"""Fleet calibration bench: chips/sec calibrated, batched-vs-per-chip
speedup, and the per-chip retrace counter (the ISSUE 5 regression
metric).

Per backend it programs a fleet of N chips plus the N independent
``Deployment``s the fleet must match, ages everything 24h, then times

  * the per-chip loop — N sequential ``Deployment.calibrate`` calls,
    each re-tracing its own step and re-running the teacher forward
    (what the public single-chip API costs today), and
  * ONE batched ``Fleet.calibrate`` — one shared teacher-feature cache,
    one vmapped jitted step for the whole fleet,

checks the fleet result is bitwise the per-chip result (per-step losses
compared chip-by-chip), and re-runs the same-shape fleet calibration to
pin retraces at zero. Serving two chips afterwards must not grow the
serving step registry either (compiled steps are per-(cfg, backend),
not per-chip).

The model config is the CPU-scale smoke config in BOTH modes — the
subject of this bench is the CHIP axis (--smoke shrinks the fleet, the
default records the acceptance fleet of 16); absolute times are not
TPU-representative, the trajectory and the retrace counts are.

Usage:
    PYTHONPATH=src python benchmarks/fleet_bench.py --smoke \
        [--out BENCH_fleet.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def bench_backend(
    arch: str, backend: str, *, chips: int, steps: int, samples: int,
    seq_len: int, check_speedup: float,
) -> dict:
    from repro.configs import get_arch
    from repro.deploy import Deployment, serving
    from repro.fleet import Fleet, fleet_compile_count

    cfg = get_arch(arch).smoke
    fleet = Fleet.program(cfg, 0, n_chips=chips, backend=backend)
    fleet.advance(24.0)
    deps = []
    for i in range(chips):
        dep = Deployment.program(
            cfg, (fleet.teacher_key, fleet.chip_key(i)), backend=backend
        )
        dep.advance(24.0)
        deps.append(dep)
    calib = dict(batch_or_samples=samples, steps=steps, seq_len=seq_len)

    compiles_before = fleet_compile_count(cfg)
    t0 = time.perf_counter()
    fleet_report = fleet.calibrate(**calib)
    fleet_seconds = time.perf_counter() - t0
    compiles_first = fleet_compile_count(cfg) - compiles_before

    t0 = time.perf_counter()
    fleet.calibrate(**calib)  # warm: same shapes, zero new compiles
    fleet_seconds_warm = time.perf_counter() - t0
    retraces_second_run = fleet_compile_count(cfg) - compiles_before - \
        compiles_first

    t0 = time.perf_counter()
    solo_losses = [dep.calibrate(**calib).losses for dep in deps]
    loop_seconds = time.perf_counter() - t0

    losses_match = all(
        np.array_equal(
            np.asarray(solo_losses[i], np.float32), fleet_report.losses[:, i]
        )
        for i in range(chips)
    )

    # serving two chips reuses one compiled decode stack
    prompt = np.zeros((1, 4), np.int32)
    s0 = fleet.serve(0)
    s0.generate(jax.numpy.asarray(prompt), gen_len=3)
    with s0.scope():
        warm = serving.compile_count(cfg)
    fleet.serve(min(1, chips - 1)).generate(jax.numpy.asarray(prompt), gen_len=3)
    with s0.scope():
        serve_retraces = serving.compile_count(cfg) - warm

    speedup = loop_seconds / max(fleet_seconds, 1e-9)
    result = {
        "chips": chips,
        "steps": steps,
        "samples": samples,
        "per_chip_loop_seconds": round(loop_seconds, 4),
        "fleet_seconds": round(fleet_seconds, 4),
        "fleet_seconds_warm": round(fleet_seconds_warm, 4),
        "speedup_vs_per_chip_loop": round(speedup, 2),
        "chips_per_sec_loop": round(chips / max(loop_seconds, 1e-9), 3),
        "chips_per_sec_fleet": round(chips / max(fleet_seconds, 1e-9), 3),
        "chips_per_sec_fleet_warm": round(
            chips / max(fleet_seconds_warm, 1e-9), 3
        ),
        "fleet_compiles_first_run": compiles_first,
        "per_chip_retraces_second_run": retraces_second_run,
        "serve_retraces_second_chip": serve_retraces,
        "losses_bitwise_match": bool(losses_match),
        "sram_bytes_per_chip": fleet_report.sram_bytes_per_chip,
        "calibrated_fraction": round(fleet_report.calibrated_fraction, 6),
    }
    violations = []
    if retraces_second_run != 0:
        violations.append(f"fleet recalibration retraced {retraces_second_run}x")
    if serve_retraces != 0:
        violations.append(f"serving chip 2 retraced {serve_retraces}x")
    if not losses_match:
        violations.append("fleet losses diverge from per-chip loop")
    if check_speedup and speedup < check_speedup:
        violations.append(
            f"speedup {speedup:.2f}x < required {check_speedup:.1f}x"
        )
    if violations:
        result["violations"] = violations
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet, fewer steps (CI lane; still fails "
                         "on any per-chip retrace)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--backends", default="dequant,codes")
    ap.add_argument("--chips", type=int, default=None,
                    help="fleet size (default: 4 smoke / 16 full)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args()

    chips = args.chips or (4 if args.smoke else 16)
    steps = 2 if args.smoke else 8
    samples = 4 if args.smoke else 8
    seq_len = 16 if args.smoke else 32
    # the >=4x acceptance gate applies to the recorded full-mode run;
    # the smoke lane only gates retraces/parity (tiny fleets can't
    # amortize the vmapped compile)
    check_speedup = 0.0 if args.smoke else 4.0

    result = {
        "bench": "fleet_calibration",
        "arch": args.arch,
        "mode": "smoke" if args.smoke else "full",
        "backends": {},
    }
    failures = 0
    for backend in args.backends.split(","):
        try:
            result["backends"][backend] = bench_backend(
                args.arch, backend, chips=chips, steps=steps,
                samples=samples, seq_len=seq_len,
                check_speedup=check_speedup,
            )
        except Exception as e:  # keep the suite going; fail at the end
            result["backends"][backend] = {"error": repr(e)}
            failures += 1
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(result, indent=2, sort_keys=True))
    violated = any(
        b.get("violations") for b in result["backends"].values()
        if isinstance(b, dict)
    )
    if failures or violated:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
