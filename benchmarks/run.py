"""Benchmark runner: one function per paper table/figure + kernel
micro-benches. Prints ``name,value,derived`` CSV.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import backends_bench, paper_tables, kernels_bench

    benches = {}
    benches.update(paper_tables.ALL)
    benches.update(kernels_bench.ALL)
    benches.update(backends_bench.ALL)
    if args.only:
        keep = args.only.split(",")
        benches = {k: v for k, v in benches.items() if k in keep}

    quick = not args.full
    print("name,value,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows = fn(quick=quick)
        except Exception as e:  # keep the suite going; report at the end
            print(f"{name},NaN,ERROR: {e!r}")
            failures += 1
            continue
        for rname, val, derived in rows:
            print(f'{rname},{val},"{derived}"')
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
