"""Fault-recovery bench: DoRA calibration must measurably restore
accuracy under EVERY fault class — without a single RRAM rewrite.

Drives ``repro.faults.study.fault_recovery_study``: per fault class
(stuck-at, saturated, retention, I-V non-linearity) it programs a
deployment, ages it ``--hours`` in the field, injects the fault, and
calibrates the SRAM side-cars on the faulty base, recording the
teacher/student logit MSE at clean / faulted / calibrated. The GATE —
exit 1 — fires if any class's calibrated MSE fails to improve on its
faulted MSE: that would mean the paper's "calibrate, don't reprogram"
claim broke for that non-ideality.

The model config is the CPU-scale smoke config in both modes; the
default mode runs the paper's calibration scale (10 samples, 20 epochs)
while ``--smoke`` shrinks the calibration set for CI's fast lane. The
subject is the RECOVERY TRAJECTORY per fault class, not absolute MSE.

Usage:
    PYTHONPATH=src python benchmarks/faults_bench.py --smoke \
        [--out BENCH_faults.json]
"""
from __future__ import annotations

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI fast lane: fewer calibration samples/epochs",
    )
    ap.add_argument("--samples", type=int, default=None,
                    help="calibration samples (default: paper's 10; smoke 4)")
    ap.add_argument("--steps", type=int, default=None,
                    help="calibration epochs (default: paper's 20; smoke 12)")
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--hours", type=float, default=300.0,
                    help="field hours of drift before the fault lands")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write JSON results here")
    args = ap.parse_args()

    from repro.faults import FAULT_CLASSES, default_spec, fault_recovery_study

    samples = args.samples or (4 if args.smoke else 10)
    steps = args.steps or (12 if args.smoke else 20)
    seq_len = args.seq_len or (16 if args.smoke else 32)

    t0 = time.perf_counter()
    results = fault_recovery_study(
        args.arch, smoke=True, samples=samples, steps=steps,
        seq_len=seq_len, hours=args.hours, seed=args.seed,
    )
    elapsed = time.perf_counter() - t0

    violations = []
    for kind in FAULT_CLASSES:
        r = results[kind]
        spec = default_spec(kind, args.seed + 1)
        r["spec"] = spec.to_dict()
        recovered = r["calibrated_mse"] < r["faulted_mse"]
        r["recovered"] = bool(recovered)
        print(
            f"{kind:>16}: clean={r['clean_mse']:.3f} "
            f"faulted={r['faulted_mse']:.3f} "
            f"calibrated={r['calibrated_mse']:.3f} "
            f"(recovered {100 * r['recovered_fraction']:.0f}% of the "
            f"fault-induced error)"
        )
        if not recovered:
            violations.append(
                f"{kind}: calibration did not improve the faulted model "
                f"({r['calibrated_mse']:.4f} >= {r['faulted_mse']:.4f})"
            )

    payload = {
        "bench": "faults",
        "arch": args.arch,
        "mode": "smoke" if args.smoke else "full",
        "samples": samples,
        "steps": steps,
        "seq_len": seq_len,
        "hours": args.hours,
        "seed": args.seed,
        "elapsed_seconds": round(elapsed, 2),
        "classes": results,
        "violations": violations,
    }
    out = json.dumps(payload, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(out + "\n")
        print(f"wrote {args.out}")
    else:
        print(out)

    if violations:
        print("FAULT RECOVERY GATE FAILED:")
        for v in violations:
            print(f"  - {v}")
        raise SystemExit(1)
    print(f"all {len(FAULT_CLASSES)} fault classes recovered "
          f"({elapsed:.1f}s)")


if __name__ == "__main__":
    main()
