"""Calibration registry bench: steps-to-converge economics of fleet
warm-start (the ISSUE 8 measurable claim).

Two identical fleets live through the same maintenance timeline — age,
recalibrate, age again, recalibrate — with every recalibration paying
from freshly reset (output-preserving) adapters, the way a new
maintenance process would:

  * COLD arm: every recalibration starts from zeros.
  * REGISTRY arm: recalibrations record into a ``CalibrationRegistry``
    and warm-start adapters + optimizer from each chip's nearest stable
    reference before training.

The convergence target is the cold arm's own achieved loss: a first
pass runs the cold arm to its full step budget and takes each cycle's
final max-chip loss as that cycle's target; the measured pass then runs
BOTH arms with ``loss_threshold`` early-stopping at those targets. The
fleet lifecycle is deterministic, so the cold arm replays its first
pass exactly and spends the full budget, while the registry arm stops
as soon as its warm-started chips are at or below the loss the cold arm
only reaches at the end. The bench gates on the registry arm spending
strictly fewer total chip-epochs AND its final loss staying within
tolerance of the cold arm's. Cycle 1 is identical by construction — the
registry is empty — so all savings are earned on later cycles.

Usage:
    PYTHONPATH=src python benchmarks/registry_bench.py --smoke \
        [--out BENCH_registry.json]
"""
from __future__ import annotations

import argparse
import json
import tempfile
from typing import List, Optional

import numpy as np


def run_arm(
    arch: str, backend: str, *, chips: int, cycles: int, steps: int,
    samples: int, seq_len: int, hours: float,
    thresholds: Optional[List[float]], registry_root: Optional[str],
) -> dict:
    from repro.configs import get_arch
    from repro.fleet import Fleet
    from repro.registry import CalibrationRegistry

    cfg = get_arch(arch).smoke
    fleet = Fleet.program(cfg, 0, n_chips=chips, backend=backend)
    registry = (
        CalibrationRegistry(registry_root) if registry_root else None
    )
    reg_args = (
        {"registry": registry, "warm_start": True} if registry else {}
    )
    chip_epochs = 0
    warm_chips = 0
    losses = []
    for c in range(cycles):
        fleet.advance(hours)
        # every cycle models a fresh maintenance process: without the
        # registry the adapters start over from zeros
        fleet.reset_adapters()
        rep = fleet.calibrate(
            samples, steps=steps, seq_len=seq_len,
            loss_threshold=thresholds[c] if thresholds else 0.0,
            **reg_args,
        )
        chip_epochs += rep.epochs_run * chips
        warm_chips += len(rep.warm_started_chips)
        losses.append([float(x) for x in np.asarray(rep.losses)[-1]])
    return {
        "chip_epochs": chip_epochs,
        "chip_epoch_budget": steps * chips * cycles,
        "warm_started_chips": warm_chips,
        "final_loss_per_chip": losses[-1],
        "final_loss_max": max(losses[-1]),
        "per_cycle_final_loss": losses,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet, short timeline (CI lane; still "
                         "fails when warm-start saves zero epochs)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--backend", default="dequant")
    ap.add_argument("--chips", type=int, default=None)
    ap.add_argument("--cycles", type=int, default=None,
                    help="maintenance cycles (default: 3 smoke / 4 full)")
    ap.add_argument("--loss-tolerance", type=float, default=0.05,
                    help="registry arm's final max loss may exceed the "
                         "cold arm's by at most this relative margin")
    ap.add_argument("--out", default="BENCH_registry.json")
    args = ap.parse_args()

    chips = args.chips or (3 if args.smoke else 8)
    cycles = args.cycles or (3 if args.smoke else 4)
    steps = 8 if args.smoke else 16
    samples = 4 if args.smoke else 8
    seq_len = 16 if args.smoke else 32

    common = dict(
        chips=chips, cycles=cycles, steps=steps, samples=samples,
        seq_len=seq_len, hours=24.0,
    )
    # pass 1: the cold arm's full-budget run defines each cycle's
    # convergence target (its own final max-chip loss, + float slack)
    probe = run_arm(
        args.arch, args.backend, thresholds=None, registry_root=None,
        **common,
    )
    targets = [
        max(cycle) * (1.0 + 1e-6) for cycle in probe["per_cycle_final_loss"]
    ]
    # pass 2: both arms run to the same targets; the cold arm replays
    # its probe deterministically
    cold = run_arm(
        args.arch, args.backend, thresholds=targets, registry_root=None,
        **common,
    )
    with tempfile.TemporaryDirectory() as root:
        warm = run_arm(
            args.arch, args.backend, thresholds=targets,
            registry_root=root, **common,
        )

    saved = cold["chip_epochs"] - warm["chip_epochs"]
    result = {
        "bench": "registry_warmstart",
        "arch": args.arch,
        "backend": args.backend,
        "mode": "smoke" if args.smoke else "full",
        "chips": chips,
        "cycles": cycles,
        "steps_per_cycle": steps,
        "loss_targets": [round(t, 6) for t in targets],
        "cold": cold,
        "registry": warm,
        "chip_epochs_saved": saved,
        "chip_epochs_saved_pct": round(
            100.0 * saved / max(cold["chip_epochs"], 1), 2
        ),
    }
    violations = []
    if saved <= 0:
        violations.append(
            f"warm-start saved {saved} chip-epochs (must be > 0)"
        )
    limit = cold["final_loss_max"] * (1.0 + args.loss_tolerance)
    if warm["final_loss_max"] > limit:
        violations.append(
            f"registry final loss {warm['final_loss_max']:.6f} exceeds "
            f"cold {cold['final_loss_max']:.6f} by more than "
            f"{100 * args.loss_tolerance:.0f}%"
        )
    if warm["warm_started_chips"] == 0:
        violations.append("no chip ever warm-started")
    if violations:
        result["violations"] = violations
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(result, indent=2, sort_keys=True))
    if violations:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
