"""Render the §Roofline markdown table from dryrun JSON artifacts.

Usage: PYTHONPATH=src python -m benchmarks.roofline_table \
           artifacts/roofline_baseline.json artifacts/roofline_final.json
"""
import json
import sys


WHAT_MOVES = {
    "compute": "more MXU-efficient tiling / lower-precision matmuls",
    "memory": "fuse attention chain (flash kernel) / int8 weights in HBM",
    "collective": "overlap TP collectives with compute; reshard hot tensor",
}


def load(path):
    rows = json.load(open(path))
    return {(r["arch"], r["shape"]): r for r in rows}


def fmt(r, base=None):
    def ms(x):
        return f"{x*1e3:9.1f}"

    delta = ""
    if base is not None and base["step_time_s"] > 0:
        ratio = base["step_time_s"] / max(r["step_time_s"], 1e-12)
        delta = f" | {ratio:5.1f}x"
    return (
        f"| {r['arch']} | {r['shape']} | {ms(r['compute_s'])} | "
        f"{ms(r['memory_s'])} | {ms(r['collective_s'])} | {r['bottleneck']} | "
        f"{r['useful_flop_ratio']:.2f} | {r['roofline_fraction']*100:5.2f}%"
        f"{delta} |"
    )


def main():
    base = load(sys.argv[1])
    final = load(sys.argv[2]) if len(sys.argv) > 2 else None
    print(
        "| arch | shape | compute ms | memory ms | collective ms | "
        "bottleneck | useful | roofline | speedup vs baseline |"
    )
    print("|---|---|---|---|---|---|---|---|---|")
    keys = sorted(final.keys() if final else base.keys())
    for k in keys:
        r = (final or base)[k]
        print(fmt(r, base.get(k) if final else None))
    # bottleneck guidance footer
    print()
    for b, fix in WHAT_MOVES.items():
        print(f"* {b}-bound cells: {fix}")


if __name__ == "__main__":
    main()
