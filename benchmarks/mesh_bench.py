"""Mesh-native lifecycle bench: sharded-vs-single-device serve parity,
tensor-parallel decode tok/s, elastic re-mesh recovery exactness, and
mesh fleet-calibration parity — the ISSUE 9 acceptance gates as one
JSON artifact.

Forces 8 CPU devices BEFORE importing jax (the CI lane also exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; setdefault keeps
an explicit environment override in charge). On this container the
codes backend's Pallas kernel runs in interpret mode, so absolute
tok/s is not TPU-representative; the numbers that matter are the
PARITY bits (all must be exact), the compressed-calibration deviation
(must be small but nonzero), and their trajectory over PRs.

Regression gates (exit 1):
  * sharded decode tokens differ from single-device (bitwise gate),
  * nothing was actually sharded (vacuous parity),
  * re-mesh replay changes any in-flight request's stream,
  * mesh fleet calibration (uncompressed) not bitwise, or the
    compressed path drifts past tolerance / not at all.

Usage:
    PYTHONPATH=src python benchmarks/mesh_bench.py --smoke \
        [--out BENCH_mesh.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import jax  # noqa: E402
import numpy as np  # noqa: E402


def _prompts(cfg, n, seed=0):
    lens = [4 + (3 * i) % 9 for i in range(n)]
    return [
        np.asarray(jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(seed), i),
            (plen,), 0, cfg.vocab,
        ))
        for i, plen in enumerate(lens)
    ]


def _run_engine(session, prompts, *, max_new, max_slots, max_len,
                remesh_at=None):
    from repro.deploy import ServeEngine

    eng = ServeEngine(session, max_slots=max_slots, max_len=max_len)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    plan, remesh_s = None, 0.0
    n = 0
    while eng.step():
        n += 1
        if remesh_at is not None and n == remesh_at:
            t0 = time.perf_counter()
            plan = eng.remesh()
            remesh_s = time.perf_counter() - t0
    return [r.tokens for r in reqs], eng.stats(), plan, remesh_s


def bench_serve(arch: str, quick: bool) -> tuple[dict, list]:
    from repro.configs import get_arch
    from repro.deploy import Deployment
    from repro.launch.mesh import make_host_mesh

    cfg = get_arch(arch).smoke if quick else get_arch(arch).full
    n_req, max_new, max_slots, max_len = (
        (4, 6, 2, 32) if quick else (16, 32, 8, 256)
    )
    prompts = _prompts(cfg, n_req)
    dep = Deployment.program(cfg, 0, backend="codes")
    kw = dict(max_new=max_new, max_slots=max_slots, max_len=max_len)

    gate_msgs = []
    ref_toks, ref_stats, _, _ = _run_engine(dep.serve(), prompts, **kw)

    tp = dep.serve(mesh=make_host_mesh((1, 4)))
    tp_toks, tp_stats, _, _ = _run_engine(tp, prompts, **kw)
    if tp.shard_stats["sharded"] == 0:
        gate_msgs.append("wrap policy sharded nothing — parity is vacuous")
    if tp_toks != ref_toks:
        gate_msgs.append("sharded decode streams differ from single-device")

    rm_toks, _, plan, remesh_s = _run_engine(
        dep.serve(mesh=make_host_mesh((2, 4))), prompts,
        remesh_at=2, **kw,
    )
    if rm_toks != ref_toks:
        gate_msgs.append("re-mesh replay changed an in-flight stream")

    return {
        "arch": arch,
        "shard_stats": dict(tp.shard_stats),
        "decode_tok_per_s_single": round(ref_stats["decode_tok_per_s"], 2),
        "decode_tok_per_s_tp4": round(tp_stats["decode_tok_per_s"], 2),
        "sharded_bitwise_equal": tp_toks == ref_toks,
        "remesh_plan": None if plan is None else {
            "failed_hosts": plan.failed_hosts,
            "new_mesh_shape": list(plan.new_mesh_shape),
        },
        "remesh_recovery_s": round(remesh_s, 3),
        "remesh_bitwise_equal": rm_toks == ref_toks,
    }, gate_msgs


def bench_fleet(arch: str, quick: bool) -> tuple[dict, list]:
    from repro.configs import get_arch
    from repro.fleet.fleet import Fleet
    from repro.launch.mesh import make_host_mesh

    cfg = get_arch(arch).smoke if quick else get_arch(arch).full
    steps = 3 if quick else 10

    def run(mesh=None, grad_compress=False):
        fleet = Fleet.program(cfg, 0, n_chips=4, backend="dequant")
        fleet.advance(24.0)
        t0 = time.perf_counter()
        rep = fleet.calibrate(
            steps=steps, mesh=mesh, grad_compress=grad_compress
        )
        return rep, fleet, time.perf_counter() - t0

    gate_msgs = []
    rep0, f0, t_single = run()
    rep1, f1, t_mesh = run(mesh=make_host_mesh((2, 4)))
    bitwise = bool(np.array_equal(rep0.losses, rep1.losses)) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(f0.adapters),
                        jax.tree_util.tree_leaves(f1.adapters))
    )
    if not bitwise:
        gate_msgs.append("mesh fleet calibration (uncompressed) not bitwise")

    rep2, f2, _ = run(mesh=make_host_mesh((2, 4)), grad_compress=True)
    dev = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(jax.tree_util.tree_leaves(f0.adapters),
                        jax.tree_util.tree_leaves(f2.adapters))
    )
    if not np.array_equal(rep0.losses[0], rep2.losses[0]):
        gate_msgs.append("compressed path step-0 loss not exact")
    if not 0 < dev < 5e-2:
        gate_msgs.append(
            f"compressed adapter deviation {dev} outside (0, 5e-2)"
        )
    return {
        "arch": arch,
        "n_chips": 4,
        "steps": steps,
        "calib_s_single": round(t_single, 3),
        "calib_s_mesh": round(t_mesh, 3),
        "uncompressed_bitwise_equal": bitwise,
        "compressed_adapter_max_dev": dev,
    }, gate_msgs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + request counts (CI lane)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--out", default="BENCH_mesh.json")
    args = ap.parse_args()

    if jax.device_count() < 8:
        raise SystemExit(
            f"needs 8 devices, saw {jax.device_count()} — is another "
            "XLA_FLAGS value overriding the device-count forcing?"
        )

    gate_msgs = []
    result = {
        "bench": "mesh_lifecycle",
        "mode": "smoke" if args.smoke else "full",
        "devices": jax.device_count(),
    }
    try:
        result["serve"], msgs = bench_serve(args.arch, args.smoke)
        gate_msgs += msgs
    except Exception as e:
        result["serve"] = {"error": repr(e)}
        gate_msgs.append(f"serve bench errored: {e!r}")
    try:
        result["fleet"], msgs = bench_fleet(args.arch, args.smoke)
        gate_msgs += msgs
    except Exception as e:
        result["fleet"] = {"error": repr(e)}
        gate_msgs.append(f"fleet bench errored: {e!r}")

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(result, indent=2, sort_keys=True))
    for msg in gate_msgs:
        print(f"FAIL: {msg}")
    if gate_msgs:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
