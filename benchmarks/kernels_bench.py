"""Kernel micro-benchmarks: fused rimc DoRA linear vs unfused reference.

On this CPU container the Pallas kernels run in interpret mode, so
wall-times are NOT TPU-representative — the derived column reports the
analytic HBM-traffic advantage of the fused kernel instead (the number
that matters on TPU: bytes moved per output element).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import dora, rram
from repro.kernels import ops, ref

Row = Tuple[str, float, str]


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def kernel_bench(quick=True) -> List[Row]:
    rows: List[Row] = []
    shapes = [(128, 256, 256, 8)] if quick else [
        (128, 256, 256, 8), (256, 512, 512, 8), (256, 1024, 1024, 16)
    ]
    for m, k, n, r in shapes:
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        w = jax.random.normal(k1, (k, n)) * 0.02
        rcfg = rram.RramConfig(relative_drift=0.1)
        xw = rram.apply_drift(rram.program(w, rcfg), rcfg, k2)
        ad = dora.init_adapter(
            k3, k, n, dora.AdapterConfig(rank=r), w_base=rram.dequantize(xw)
        )
        x = jax.random.normal(k2, (m, k))
        gamma = ops.dora_gamma(xw, ad)
        us_fused = _time(
            lambda: ops.rimc_linear(x, xw, ad, gamma)
        )
        us_ref = _time(
            lambda: ref.dora_linear_ref(
                x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1),
                ad["lora_a"], ad["lora_b"], gamma,
            )
        )
        # analytic HBM bytes: fused reads codes (2B/weight) once and never
        # writes W_r; unfused dequant materializes bf16 W_r (write + read).
        fused_bytes = 2 * k * n + 2 * m * k + 2 * m * n
        unfused_bytes = 2 * k * n + 2 * (2 * k * n) + 2 * m * k + 2 * m * n
        rows.append(
            (f"kernel/dora_linear_{m}x{k}x{n}_r{r}_interp", us_fused,
             f"ref={us_ref:.0f}us analytic_hbm_saving="
             f"{unfused_bytes/fused_bytes:.2f}x")
        )
        # ADC-faithful crossbar MVM correctness + timing
        us_adc = _time(lambda: ops.rimc_mvm_adc(x, xw))
        rows.append(
            (f"kernel/crossbar_mvm_{m}x{k}x{n}_interp", us_adc,
             "bit-exact vs tile oracle (tests/test_kernels.py)")
        )
    return rows


ALL = {"kernels": kernel_bench}
