"""Kernel micro-benchmarks: the decode fast path (ISSUE 6).

Rows cover the three kernel-level claims the serving numbers rest on:

* decode-shaped GEMV vs the old forced bm=128 pad — M in {1, 2, 8}
  against 128-row padding of the same problem (the ``gemv_speedup``
  column; the CI smoke gate asserts it stays above the floor),
* the fused kernel at prefill shapes vs the unfused dequant reference
  (plus the analytic HBM-traffic saving that matters on TPU),
* the int8 MMA accumulation path vs f32.

On this CPU container the Pallas kernels run in interpret mode, so
wall-times are NOT TPU-representative; relative comparisons between two
interpret-mode launches of the same machinery (GEMV vs padded, int8 vs
f32) are still directionally meaningful, and the analytic bytes column
is backend-independent.

CLI: ``python benchmarks/kernels_bench.py --smoke --out BENCH_kernels.json``
exits non-zero when the decode GEMV path fails to beat the padded-128
launch by the ``--gemv-floor`` margin (default 1.2x).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import dora, rram
from repro.kernels import autotune, ops, ref
from repro.kernels.dora_linear import dora_linear

Row = Tuple[str, float, str]


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _mk(m, k, n, r):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (k, n)) * 0.02
    rcfg = rram.RramConfig(relative_drift=0.1)
    xw = rram.apply_drift(rram.program(w, rcfg), rcfg, k2)
    ad = dora.init_adapter(
        k3, k, n, dora.AdapterConfig(rank=r), w_base=rram.dequantize(xw)
    )
    x = jax.random.normal(k2, (m, k))
    gamma = ops.dora_gamma(xw, ad)
    return x, xw, ad, gamma


def _forced_128_launch(x, xw, ad, gamma):
    """The pre-ISSUE-6 decode path: pad every operand to 128 multiples
    per call and run the tiled kernel with a full 128-row M block."""
    k, n = xw.g_pos.shape[-2:]
    xp = jnp.pad(x, ((0, (-x.shape[0]) % 128), (0, (-k) % 128)))
    gp = jnp.pad(xw.g_pos, (((0, (-k) % 128)), (0, (-n) % 128)))
    gn = jnp.pad(xw.g_neg, (((0, (-k) % 128)), (0, (-n) % 128)))
    scale = jnp.pad(
        xw.scale.reshape(1, -1).astype(jnp.float32), ((0, 0), (0, (-n) % 128))
    )
    a = jnp.pad(ad["lora_a"].astype(jnp.float32), ((0, (-k) % 128), (0, 0)))
    b = jnp.pad(ad["lora_b"].astype(jnp.float32), ((0, 0), (0, (-n) % 128)))
    g = jnp.pad(gamma.astype(jnp.float32), ((0, 0), (0, (-n) % 128)))
    y = dora_linear(xp, gp, gn, scale, a, b, g, interpret=True)
    return y[: x.shape[0], :n]


def decode_rows(quick=True) -> Tuple[List[Row], List[float]]:
    rows: List[Row] = []
    speedups: List[float] = []
    k, n, r = (256, 256, 8) if quick else (1024, 1024, 8)
    for m in (1, 2, 8):
        x, xw, ad, gamma = _mk(m, k, n, r)
        us_gemv = _time(lambda: ops.rimc_linear(x, xw, ad, gamma))
        us_padded = _time(lambda: _forced_128_launch(x, xw, ad, gamma))
        speedup = us_padded / max(us_gemv, 1e-9)
        speedups.append(speedup)
        plan = autotune.select_tiles(m, k, n, r, interpret=True)
        rows.append((
            f"kernel/decode_gemv_m{m}_{k}x{n}_r{r}_interp", us_gemv,
            f"padded128={us_padded:.0f}us gemv_speedup={speedup:.2f}x "
            f"plan=({plan.bm},{plan.bn},{plan.bk})",
        ))
    # int8 MMA at a decode shape
    x, xw, ad, gamma = _mk(2, k, n, r)
    us_f32 = _time(lambda: ops.rimc_linear(x, xw, ad, gamma))
    us_i8 = _time(lambda: ops.rimc_linear(x, xw, ad, gamma, accum="int8"))
    rows.append((
        f"kernel/decode_int8_m2_{k}x{n}_r{r}_interp", us_i8,
        f"f32={us_f32:.0f}us (interpret-mode ratio; int8 wins on MXU "
        f"byte traffic, not on a CPU emulation)",
    ))
    return rows, speedups


def prefill_rows(quick=True) -> List[Row]:
    rows: List[Row] = []
    shapes = [(128, 256, 256, 8)] if quick else [
        (128, 256, 256, 8), (256, 512, 512, 8), (256, 1024, 1024, 16)
    ]
    for m, k, n, r in shapes:
        x, xw, ad, gamma = _mk(m, k, n, r)
        us_fused = _time(lambda: ops.rimc_linear(x, xw, ad, gamma))
        us_ref = _time(
            lambda: ref.dora_linear_ref(
                x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1),
                ad["lora_a"], ad["lora_b"], gamma,
            )
        )
        # analytic HBM bytes: fused reads codes (2B/weight) once and never
        # writes W_r; unfused dequant materializes bf16 W_r (write + read).
        fused_bytes = 2 * k * n + 2 * m * k + 2 * m * n
        unfused_bytes = 2 * k * n + 2 * (2 * k * n) + 2 * m * k + 2 * m * n
        rows.append(
            (f"kernel/dora_linear_{m}x{k}x{n}_r{r}_interp", us_fused,
             f"ref={us_ref:.0f}us analytic_hbm_saving="
             f"{unfused_bytes/fused_bytes:.2f}x")
        )
        # ADC-faithful crossbar MVM correctness + timing
        us_adc = _time(lambda: ops.rimc_mvm_adc(x, xw))
        rows.append(
            (f"kernel/crossbar_mvm_{m}x{k}x{n}_interp", us_adc,
             "bit-exact vs tile oracle (tests/test_kernels.py)")
        )
    return rows


def kernel_bench(quick=True) -> List[Row]:
    d_rows, _ = decode_rows(quick)
    return d_rows + prefill_rows(quick)


ALL = {"kernels": kernel_bench}


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true", help="small shapes")
    p.add_argument("--out", default="BENCH_kernels.json")
    p.add_argument(
        "--gemv-floor", type=float, default=1.2,
        help="min acceptable decode GEMV speedup over the padded-128 "
        "launch (regression gate)",
    )
    args = p.parse_args()
    d_rows, speedups = decode_rows(quick=args.smoke)
    rows = d_rows + prefill_rows(quick=args.smoke)
    for name, us, note in rows:
        print(f"{name:48s} {us:10.0f}us  {note}")
    payload = {
        "mode": "smoke" if args.smoke else "full",
        "interpret": True,
        "rows": [
            {"name": name, "us": round(us, 1), "note": note}
            for name, us, note in rows
        ],
        "gemv_speedups": [round(s, 3) for s in speedups],
        "gemv_floor": args.gemv_floor,
        "tile_table": {
            str(k): list(v) for k, v in autotune.tile_table().items()
        },
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    worst = min(speedups)
    if worst < args.gemv_floor:
        print(
            f"FAIL: decode GEMV speedup {worst:.2f}x below the "
            f"{args.gemv_floor:.2f}x floor"
        )
        raise SystemExit(1)
    print(f"gate OK: worst decode GEMV speedup {worst:.2f}x")


if __name__ == "__main__":
    main()
