"""Continuous-batching serve bench: decode tok/s, time-to-first-token,
and the retrace counter (compiled computations must stay flat once the
step registry is warm — the ISSUE 4 regression metric).

Drives ``ServeEngine`` with two waves of ragged, staggered requests per
backend. Wave 1 warms the per-``(cfg, backend)`` compiled steps; wave 2
reuses the same prompt shapes, so ANY new compilation it triggers is a
retrace regression (``recompiles_second_wave`` should be 0).

On this CPU container the codes backend runs its Pallas kernel in
interpret mode, so absolute wall-times are not TPU-representative; the
numbers that track the serving story are the retrace count, TTFT vs
decode split, the codes/dequant decode ratio, and their trajectory over
PRs.

Regression gates (exit 1):
  * any backend errors, or recompiles in the second (same-shape) wave,
  * ``compile_count_warm`` differs between codes and dequant (the
    registry-key collision bug made codes compile 2x),
  * codes decode tok/s falls below ``--codes-floor`` x dequant's (the
    ISSUE 6 fast-path ratchet; the committed BENCH_serve.json shows the
    ratio at or above 1.0).

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
        [--out BENCH_serve.json] [--codes-floor 0.9]
"""
from __future__ import annotations

import argparse
import json
import statistics

import jax
import numpy as np


def bench_backend(arch: str, backend: str, *, quick: bool) -> dict:
    from repro.configs import get_arch
    from repro.deploy import Deployment, ServeEngine, serving

    cfg = get_arch(arch).smoke if quick else get_arch(arch).full
    n_requests, max_new, max_slots, max_len = (
        (4, 6, 2, 32) if quick else (16, 32, 8, 256)
    )
    prompt_lens = [4 + (3 * i) % 9 for i in range(n_requests)]
    session = Deployment.program(cfg, 0, backend=backend).serve()

    def wave(seed: int):
        engine = ServeEngine(session, max_slots=max_slots, max_len=max_len)
        reqs = []
        for i, plen in enumerate(prompt_lens):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(jax.random.PRNGKey(seed), i),
                (plen,), 0, cfg.vocab,
            ))
            reqs.append(engine.submit(prompt, max_new=max_new))
            engine.step()  # staggered admission while earlier rows decode
        engine.run()
        return engine, reqs

    engine1, reqs1 = wave(0)
    with session.scope():
        warm = serving.compile_count(cfg)
    engine2, reqs2 = wave(1)
    with session.scope():
        after = serving.compile_count(cfg)
    stats = engine2.stats()
    ttfts = [r.ttft_seconds for r in reqs2]
    return {
        "requests": n_requests,
        "max_new": max_new,
        "max_slots": max_slots,
        "ticks": stats["ticks"],
        "decode_tokens": stats["decode_tokens"],
        "decode_seconds": round(stats["decode_seconds"], 4),
        "decode_tok_per_s": round(stats["decode_tok_per_s"], 2),
        "ttft_s_mean": round(statistics.mean(ttfts), 4),
        "ttft_s_max": round(max(ttfts), 4),
        "compile_count_warm": warm,
        "recompiles_second_wave": after - warm,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + request counts (CI lane)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--backends", default="dequant,codes")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument(
        "--codes-floor", type=float, default=0.9,
        help="min acceptable codes/dequant decode tok/s ratio (gate; "
        "slack below 1.0 absorbs CI timer noise — the committed "
        "BENCH_serve.json is regenerated at >= 1.0)",
    )
    args = ap.parse_args()

    result = {
        "bench": "serve_engine",
        "arch": args.arch,
        "mode": "smoke" if args.smoke else "full",
        "backends": {},
    }
    failures = 0
    for backend in args.backends.split(","):
        try:
            result["backends"][backend] = bench_backend(
                args.arch, backend, quick=args.smoke
            )
        except Exception as e:  # keep the suite going; fail at the end
            result["backends"][backend] = {"error": repr(e)}
            failures += 1
    backends = result["backends"]
    codes, dequant = backends.get("codes"), backends.get("dequant")
    gate_msgs = []
    if (
        isinstance(codes, dict) and isinstance(dequant, dict)
        and "decode_tok_per_s" in codes and "decode_tok_per_s" in dequant
    ):
        ratio = codes["decode_tok_per_s"] / max(
            dequant["decode_tok_per_s"], 1e-9
        )
        result["codes_vs_dequant_tok_ratio"] = round(ratio, 3)
        result["codes_floor"] = args.codes_floor
        if ratio < args.codes_floor:
            gate_msgs.append(
                f"codes/dequant decode ratio {ratio:.3f} below the "
                f"{args.codes_floor:.2f} floor"
            )
        if codes["compile_count_warm"] != dequant["compile_count_warm"]:
            gate_msgs.append(
                "compile_count_warm mismatch: codes="
                f"{codes['compile_count_warm']} "
                f"dequant={dequant['compile_count_warm']}"
            )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(result, indent=2, sort_keys=True))
    retraces = [
        b.get("recompiles_second_wave") for b in backends.values()
        if isinstance(b, dict) and "recompiles_second_wave" in b
    ]
    for msg in gate_msgs:
        print(f"FAIL: {msg}")
    if failures or any(r != 0 for r in retraces) or gate_msgs:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
