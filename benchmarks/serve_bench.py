"""Continuous-batching serve bench: decode tok/s, time-to-first-token,
prefix-cache reuse, and the retrace counter (compiled computations must
stay flat once the step registry is warm — the ISSUE 4 regression
metric) — swept over EVERY config in the zoo, encoder-decoder and
vision-prefix lanes included.

Per architecture, the bench drives ``ServeEngine`` with two waves of
ragged, staggered requests. Wave 1 warms the per-``(cfg, backend)``
compiled steps; wave 2 reuses the same request shapes, so ANY new
compilation it triggers is a retrace regression
(``recompiles_second_wave`` must be 0 — including the encoder and
vision-prefill lanes, whose admission programs live in the same
registry). A third phase admits one prompt cold, then resubmits it: the
resubmission must hit the prefix cache, emit bitwise-identical tokens,
and land a lower TTFT (the snapshot skips the prompt's prefill).

The primary arch (``--arch``) additionally runs on BOTH substrate
backends for the codes/dequant decode-ratio gate; the rest of the zoo
sweeps on the codes backend (the paper's serving path).

On this CPU container the codes backend runs its Pallas kernel in
interpret mode, so absolute wall-times are not TPU-representative; the
numbers that track the serving story are the retrace count, TTFT vs
decode split, prefix-hit TTFT vs cold, the codes/dequant decode ratio,
and their trajectory over PRs.

Regression gates (exit 1):
  * any arch/backend errors, or recompiles in a second (same-shape)
    wave — enc-dec and vision lanes included,
  * a prefix-cache resubmission that misses, mismatches the cold
    tokens, or fails to lower TTFT,
  * ``compile_count_warm`` differs between codes and dequant on the
    primary arch (the registry-key collision bug made codes compile
    2x),
  * primary-arch codes decode tok/s below ``--codes-floor`` x
    dequant's (the ISSUE 6 fast-path ratchet).

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py --smoke \
        [--out BENCH_serve.json] [--codes-floor 0.9]
"""
from __future__ import annotations

import argparse
import json
import statistics

import jax
import numpy as np

ZOO = [
    "qwen3_1_7b", "gemma3_12b", "minitron_8b", "deepseek_coder_33b",
    "deepseek_v2_lite_16b", "mixtral_8x22b", "falcon_mamba_7b",
    "recurrentgemma_9b", "seamless_m4t_large_v2", "paligemma_3b",
]


def _request_inputs(cfg, seed: int, i: int, plen: int):
    """Deterministic (prompt, enc_embeds, patch_embeds) for request i —
    the same seed reproduces the same bytes, which is what lets wave 2
    reuse wave 1's shapes and the prefix phase re-hash its prompt."""
    k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
    kp, ke, kv = jax.random.split(k, 3)
    prompt = np.asarray(jax.random.randint(kp, (plen,), 0, cfg.vocab))
    enc = None
    if cfg.encoder_layers:
        enc = np.asarray(jax.random.normal(
            ke, (3 + i % 2, cfg.d_model), cfg.dtype
        ))
    patches = None
    if cfg.vision_tokens:
        patches = np.asarray(jax.random.normal(
            kv, (cfg.vision_tokens, cfg.d_model), cfg.dtype
        ))
    return prompt, enc, patches


def bench_arch(arch: str, backend: str, *, quick: bool) -> dict:
    from repro.configs import get_arch
    from repro.deploy import Deployment, ServeEngine

    cfg = get_arch(arch).smoke if quick else get_arch(arch).full
    n_requests, max_new, max_slots = (4, 6, 2) if quick else (16, 32, 8)
    max_len = (48 if quick else 256) + cfg.vision_tokens
    chunk = 8 if quick else 32
    prompt_lens = [4 + (3 * i) % 9 for i in range(n_requests)]
    session = Deployment.program(cfg, 0, backend=backend).serve()
    src_len = 4 if cfg.encoder_layers else 0

    def engine():
        return ServeEngine(
            session, max_slots=max_slots, max_len=max_len, src_len=src_len,
            prefill_chunk=chunk, min_bucket=4,
        )

    def wave(seed: int):
        eng = engine()
        reqs = []
        for i, plen in enumerate(prompt_lens):
            prompt, enc, patches = _request_inputs(cfg, seed, i, plen)
            reqs.append(eng.submit(
                prompt, max_new=max_new, enc_embeds=enc, patch_embeds=patches
            ))
            eng.step()  # staggered admission while earlier rows decode
        eng.run()
        return eng, reqs

    engine1, _ = wave(0)
    warm = engine1.compile_count()
    engine2, reqs2 = wave(1)
    after = engine2.compile_count()
    stats = engine2.stats()
    ttfts = [r.ttft_seconds for r in reqs2]

    # prefix phase: cold admission, then an exact resubmission — must
    # hit the snapshot, reproduce the cold tokens bitwise, and beat the
    # cold TTFT. A throwaway different-token admission first warms any
    # length-16 program (fused-prefill archs) so the cold TTFT measures
    # computation, not compilation.
    eng = engine()
    pw, ew, vw = _request_inputs(cfg, 8, 0, 16)
    eng.submit(pw, max_new=2, enc_embeds=ew, patch_embeds=vw)
    eng.run()
    prompt, enc, patches = _request_inputs(cfg, 7, 0, 16)
    cold = eng.submit(
        prompt, max_new=max_new, enc_embeds=enc, patch_embeds=patches
    )
    eng.run()
    hit = eng.submit(
        prompt, max_new=max_new, enc_embeds=enc, patch_embeds=patches
    )
    eng.run()
    pstats = eng.stats()
    prefix_ok = (
        hit.prefix_hit_tokens == prompt.shape[0]
        and hit.tokens == cold.tokens
        and hit.ttft_seconds < cold.ttft_seconds
    )
    return {
        "requests": n_requests,
        "max_new": max_new,
        "max_slots": max_slots,
        "prefill_chunk": chunk,
        "ticks": stats["ticks"],
        "decode_tokens": stats["decode_tokens"],
        "decode_seconds": round(stats["decode_seconds"], 4),
        "decode_tok_per_s": round(stats["decode_tok_per_s"], 2),
        "ttft_s_mean": round(statistics.mean(ttfts), 4),
        "ttft_s_max": round(max(ttfts), 4),
        "compile_count_warm": warm,
        "recompiles_second_wave": after - warm,
        "ttft_s_prefix_cold": round(cold.ttft_seconds, 4),
        "ttft_s_prefix_hit": round(hit.ttft_seconds, 4),
        "prefix_hit_rate": round(
            (pstats["prefix_hits"] + pstats["prefix_partial_hits"])
            / max(pstats["prefix_lookups"], 1), 3,
        ),
        "prefix_gate_ok": bool(prefix_ok),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + request counts (CI lane)")
    ap.add_argument("--arch", default="qwen3-1.7b",
                    help="primary arch: benched on both backends + ratio gate")
    ap.add_argument("--archs", default=",".join(ZOO),
                    help="comma list of zoo archs to sweep (codes backend)")
    ap.add_argument("--backends", default="dequant,codes")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument(
        "--codes-floor", type=float, default=0.9,
        help="min acceptable codes/dequant decode tok/s ratio (gate; "
        "slack below 1.0 absorbs CI timer noise — the committed "
        "BENCH_serve.json is regenerated at >= 1.0)",
    )
    args = ap.parse_args()
    from repro.configs import get_arch

    result = {
        "bench": "serve_engine",
        "arch": args.arch,
        "mode": "smoke" if args.smoke else "full",
        "backends": {},
        "zoo": {},
    }
    failures = []
    for backend in args.backends.split(","):
        try:
            result["backends"][backend] = bench_arch(
                args.arch, backend, quick=args.smoke
            )
        except Exception as e:  # keep the sweep going; fail at the end
            result["backends"][backend] = {"error": repr(e)}
            failures.append(f"{args.arch}/{backend}: {e!r}")
    primary = get_arch(args.arch).name
    for arch in args.archs.split(","):
        if get_arch(arch).name == primary:
            result["zoo"][arch] = {"see": "backends"}
            continue
        try:
            result["zoo"][arch] = bench_arch(arch, "codes", quick=args.smoke)
        except Exception as e:
            result["zoo"][arch] = {"error": repr(e)}
            failures.append(f"{arch}/codes: {e!r}")

    backends = result["backends"]
    codes, dequant = backends.get("codes"), backends.get("dequant")
    gate_msgs = list(failures)
    if (
        isinstance(codes, dict) and isinstance(dequant, dict)
        and "decode_tok_per_s" in codes and "decode_tok_per_s" in dequant
    ):
        ratio = codes["decode_tok_per_s"] / max(
            dequant["decode_tok_per_s"], 1e-9
        )
        result["codes_vs_dequant_tok_ratio"] = round(ratio, 3)
        result["codes_floor"] = args.codes_floor
        if ratio < args.codes_floor:
            gate_msgs.append(
                f"codes/dequant decode ratio {ratio:.3f} below the "
                f"{args.codes_floor:.2f} floor"
            )
        if codes["compile_count_warm"] != dequant["compile_count_warm"]:
            gate_msgs.append(
                "compile_count_warm mismatch: codes="
                f"{codes['compile_count_warm']} "
                f"dequant={dequant['compile_count_warm']}"
            )
    lanes = dict(backends)
    lanes.update(
        (k, v) for k, v in result["zoo"].items() if "see" not in v
    )
    for name, b in lanes.items():
        if not isinstance(b, dict) or "recompiles_second_wave" not in b:
            continue
        if b["recompiles_second_wave"] != 0:
            gate_msgs.append(
                f"{name}: {b['recompiles_second_wave']} second-wave "
                "recompiles (retrace regression)"
            )
        if not b.get("prefix_gate_ok", False):
            gate_msgs.append(
                f"{name}: prefix-cache resubmission failed the "
                "bitwise/TTFT gate"
            )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps(result, indent=2, sort_keys=True))
    for msg in gate_msgs:
        print(f"FAIL: {msg}")
    if gate_msgs:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
