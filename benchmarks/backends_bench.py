"""Substrate backend comparison: serving throughput + resident memory.

Drives the deployment lifecycle API (``repro.deploy.Deployment``) on one
smoke arch per backend (dequant float fast path vs resident uint8 codes
vs ADC-faithful codes) and reports tok/s plus the rram/sram byte
accounting. On this CPU container the Pallas kernels run in interpret
mode, so codes-backend wall-times are NOT TPU-representative — the
derived column carries the numbers that matter on TPU: resident HBM
bytes per weight (codes keep 2 B/weight of uint8 and never materialize a
float W_r) and the SRAM side-car footprint the calibration trains
(paper's ~2.3% params headline).
"""
from __future__ import annotations

from typing import List, Tuple

import jax

Row = Tuple[str, float, str]


def backends_bench(quick=True) -> List[Row]:
    from repro.configs import get_arch
    from repro.deploy import Deployment

    arch = "qwen3_1_7b"
    cfg = get_arch(arch).smoke
    batch, prompt_len, gen = (2, 4, 4) if quick else (4, 16, 16)
    prompt = jax.random.randint(
        jax.random.PRNGKey(0), (batch, prompt_len), 0, cfg.vocab
    )
    rows: List[Row] = []
    backends = ("dequant", "codes") if quick else (
        "dequant", "codes", "codes_adc"
    )
    for backend in backends:
        dep = Deployment.program(cfg, 0, backend=backend)
        session = dep.serve()
        _, dt = session.generate(prompt, gen_len=gen)
        # dt times the decode steps only; the first token per stream is
        # sampled from prefill logits, so gen - 1 tokens are decode-timed
        tps = batch * (gen - 1) / dt
        resident = dep.rram_bytes()
        kind = "measured" if backend != "dequant" else "estimated"
        rows.append(
            (
                f"substrate/{arch}_serve_{backend}_toks_per_s",
                tps,
                f"rram_bytes={resident} ({kind}); "
                f"sram_bytes={dep.sram_bytes()} "
                f"({dep.calibrated_fraction():.2%} params calibrated)",
            )
        )
    return rows


ALL = {"substrate_backends": backends_bench}
