"""Substrate backend comparison: serving throughput + resident memory.

Drives ``launch/serve.py``'s generate loop on one smoke arch per backend
(dequant float fast path vs resident uint8 codes vs ADC-faithful codes)
and reports tok/s plus the rram_bytes accounting. On this CPU container
the Pallas kernels run in interpret mode, so codes-backend wall-times are
NOT TPU-representative — the derived column carries the number that
matters on TPU: resident HBM bytes per weight (codes keep 2 B/weight of
uint8 and never materialize a float W_r).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def backends_bench(quick=True) -> List[Row]:
    from repro.configs import get_arch
    from repro.core.calibrate import rram_bytes
    from repro.launch import serve
    from repro.models import transformer as T

    arch = "qwen3_1_7b"
    cfg = get_arch(arch).smoke
    batch, prompt_len, gen = (2, 4, 4) if quick else (4, 16, 16)
    prompt = jax.random.randint(
        jax.random.PRNGKey(0), (batch, prompt_len), 0, cfg.vocab
    )
    rows: List[Row] = []
    backends = ("dequant", "codes") if quick else (
        "dequant", "codes", "codes_adc"
    )
    for backend in backends:
        params = serve.load_student(cfg, seed=0, backend=backend)
        with serve.backend_scope(backend, cfg):
            _, dt = serve.generate(params, prompt, cfg, gen_len=gen)
        tps = batch * gen / dt
        resident = rram_bytes(params["base"])
        n_base, _ = T.count_params(params)
        kind = "measured" if backend != "dequant" else "estimated"
        rows.append(
            (
                f"substrate/{arch}_serve_{backend}_toks_per_s",
                tps,
                f"rram_bytes={resident} ({kind}); "
                f"{resident / max(n_base, 1):.2f} B/weight resident",
            )
        )
    return rows


ALL = {"substrate_backends": backends_bench}
