"""Benchmarks reproducing each paper table/figure.

Each function returns a list of CSV rows ``(name, value, derived)`` and is
callable standalone or via ``python -m benchmarks.run``. Settings are
scaled to a single CPU core; --full uses paper-scale epochs/sizes.

Mapping (DESIGN.md §8):
  fig2_drift_sweep     — Fig. 2: accuracy vs relative drift
  fig4_dataset_size    — Fig. 4: calib-set size, feature-DoRA vs backprop
  fig5_rank_sweep      — Fig. 5: post-calibration accuracy vs rank r
  fig6_lora_vs_dora    — Fig. 6: LoRA vs DoRA at drift 0.15 / 0.20
  table1_lifespan      — Table I: lifespan + speed analytical model
  eq7_param_ratio      — Eq. 7: gamma for ResNet-20/-50 and each LM arch
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax

from repro.core import rram
from repro.core.dora import param_ratio
from repro.core.repro_experiments import ReproResult, run_cell
from repro.core.resnet import ResnetConfig, procedural_dataset
from repro.core import repro_experiments as rx
from repro.core import resnet

Row = Tuple[str, float, str]


def _quick_cfg(quick: bool) -> ResnetConfig:
    # depth 8 (n=1) for quick CI; depth 20 (the paper's CIFAR model) for full
    return ResnetConfig(depth=8 if quick else 20, classes=20 if quick else 100)


@functools.lru_cache(maxsize=4)
def _shared_setup(quick: bool, seed: int = 0):
    """Teacher + data are shared across cells (the paper holds them fixed)."""
    cfg = _quick_cfg(quick)
    key = jax.random.PRNGKey(seed)
    k_data, k_teacher = jax.random.split(key)
    n_train = 1024 if quick else 2048
    train = procedural_dataset(k_data, n_train, cfg)
    test = procedural_dataset(jax.random.fold_in(k_data, 7), 1024, cfg)
    teacher = rx.train_teacher(
        k_teacher, cfg, *train, epochs=8 if quick else 15
    )
    acc = resnet.accuracy(teacher, *test, cfg)
    return cfg, teacher, train + test, acc


def _cell(quick, **kw) -> ReproResult:
    cfg, teacher, data, _ = _shared_setup(quick)
    if kw.get("method") in ("dora", "lora"):
        cfg = dataclasses.replace(
            cfg,
            adapter=dataclasses.replace(
                cfg.adapter, rank=kw.get("rank", 2), kind=kw["method"]
            ),
        )
    return run_cell(
        cfg=cfg, teacher=teacher, data=data,
        calib_epochs=10 if quick else 20, **kw,
    )


def fig2_drift_sweep(quick=True) -> List[Row]:
    cfg, teacher, data, teacher_acc = _shared_setup(quick)
    rows = [("fig2/teacher_acc", teacher_acc, "clean accuracy")]
    for drift in (0.05, 0.10, 0.15, 0.20):
        student = rx.make_student(
            teacher, drift, jax.random.PRNGKey(int(drift * 100))
        )
        acc = resnet.accuracy(student, data[2], data[3], cfg)
        rows.append(
            (f"fig2/drifted_acc@{drift:.2f}", acc,
             "accuracy after conductance drift, no calibration")
        )
    return rows


def fig4_dataset_size(quick=True) -> List[Row]:
    rows = []
    sizes = (1, 10, 100) if quick else (1, 10, 100, 500)
    for n in sizes:
        r = _cell(quick, method="dora", rank=2, drift=0.20, samples=n)
        rows.append(
            (f"fig4/feature_dora@{n}", r.calibrated_acc,
             f"drifted={r.drifted_acc:.3f} teacher={r.teacher_acc:.3f}")
        )
        b = _cell(quick, method="backprop", drift=0.20, samples=n)
        rows.append(
            (f"fig4/backprop@{n}", b.calibrated_acc,
             "full-parameter CE fine-tune (would write RRAM)")
        )
    return rows


def fig5_rank_sweep(quick=True) -> List[Row]:
    rows = []
    for r_ in (1, 2, 4, 8):
        r = _cell(quick, method="dora", rank=r_, drift=0.20, samples=10)
        rows.append(
            (f"fig5/dora_r{r_}", r.calibrated_acc,
             f"trainable_frac={r.trainable_fraction:.4f}")
        )
    return rows


def fig6_lora_vs_dora(quick=True) -> List[Row]:
    rows = []
    for drift in (0.15, 0.20):
        for method in ("lora", "dora"):
            for r_ in ((1, 8) if quick else (1, 2, 4, 8)):
                r = _cell(
                    quick, method=method, rank=r_, drift=drift, samples=10
                )
                rows.append(
                    (f"fig6/{method}_r{r_}@{drift:.2f}", r.calibrated_acc,
                     f"drifted={r.drifted_acc:.3f}")
                )
    return rows


def table1_lifespan(quick=True) -> List[Row]:
    """Pure analytical model — must match the paper's arithmetic exactly."""
    bp = rram.lifespan_calibrations(samples=120, epochs=20, batch=1, on_rram=True)
    ours = rram.lifespan_calibrations(samples=10, epochs=20, batch=1, on_rram=False)
    speed = rram.calibration_speedup(base_samples=125, dora_samples=10)
    return [
        ("table1/backprop_lifespan", bp, "paper: 41667 calibrations"),
        ("table1/dora_lifespan", ours, "paper: 5e13 calibrations"),
        ("table1/speedup", speed, "paper: 1250x"),
    ]


def eq7_param_ratio(quick=True) -> List[Row]:
    rows = [
        ("eq7/resnet20_r1_proxy", param_ratio(144, 16, 1),
         "paper: 4.46% overall for ResNet-20 r=1 (per-layer proxy: 3x3x16 conv)"),
        ("eq7/resnet50_r1_proxy", param_ratio(4608, 512, 1),
         "paper: 0.585% overall for ResNet-50 r=1"),
    ]
    # measured end-to-end trainable fraction on our CNN
    r = _cell(quick, method="dora", rank=4, drift=0.10, samples=10)
    rows.append(
        ("eq7/measured_fraction_r4", r.trainable_fraction,
         "adapter params / base params, whole model")
    )
    from repro.configs import ARCH_IDS, get_arch
    from repro.models import transformer as T
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id).smoke
        params = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg)
        )
        nb, na = T.count_params(params)
        rows.append(
            (f"eq7/{arch_id}_smoke", na / nb, "adapter fraction (smoke cfg)")
        )
    return rows


ALL = {
    "fig2_drift_sweep": fig2_drift_sweep,
    "fig4_dataset_size": fig4_dataset_size,
    "fig5_rank_sweep": fig5_rank_sweep,
    "fig6_lora_vs_dora": fig6_lora_vs_dora,
    "table1_lifespan": table1_lifespan,
    "eq7_param_ratio": eq7_param_ratio,
}
