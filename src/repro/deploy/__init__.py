"""Deployment lifecycle API — the repo's single public entry point.

One object carries a model from programming to drift-aware serving:

    from repro.deploy import Deployment

    dep = Deployment.program(cfg, seed, backend="codes")  # programming event
    dep.advance(hours=24)          # drift clock: field time passes
    report = dep.calibrate(10)     # SRAM side-car calibration (Alg. 1+2)
    session = dep.serve()          # merged adapters + backend scope
    toks, dt = session.generate(prompt)
    dep.snapshot("/ckpt")          # persist; Deployment.restore replays
    dep.advance(hours=168); dep.calibrate(10)   # ...and again, forever —
    # the array is never rewritten (the paper's whole point).

The legacy free functions (``launch.serve.load_student``,
``serve.backend_scope``, hand-built ``CalibState`` wiring) remain as thin
shims over this package.
"""
from repro.deploy.deployment import (  # noqa: F401
    CalibrationReport,
    Deployment,
    abstract_calib_state,
    abstract_params,
    abstract_serve_params,
)
from repro.deploy.engine import Request, ServeEngine  # noqa: F401
from repro.deploy.serving import (  # noqa: F401
    BACKENDS,
    ServeSession,
    backend_scope,
    compile_count,
    decode_step_fn,
    generate,
    prefill_and_cache,
    prefill_fn,
)


# Fleet facade: the multi-chip mirror of Deployment (program / advance /
# calibrate / serve / snapshot / restore, batched over a chip axis) and
# its drift-driven recalibration scheduler. Resolved lazily so
# ``repro.fleet`` (which builds on repro.deploy.deployment) can be
# imported first without a cycle.
_FLEET_EXPORTS = (
    "Fleet", "FleetCalibrationReport", "FleetReport",
    "RecalibrationScheduler", "fleet_compile_count",
)


def __getattr__(name):
    if name in _FLEET_EXPORTS:
        import repro.fleet as _fleet

        return getattr(_fleet, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def resnet_cell(**kwargs):
    """CNN-lifecycle entry (paper §IV Fig. 4/6 protocol): teacher ->
    drift -> calibrate -> evaluate, for the ResNet reproduction. Thin
    re-export so examples construct every experiment through
    ``repro.deploy``; see ``core/repro_experiments.run_cell``."""
    from repro.core.repro_experiments import run_cell

    return run_cell(**kwargs)
