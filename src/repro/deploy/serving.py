"""Serving half of the deployment lifecycle: backend scoping, the
prefill/decode step functions, and the ``ServeSession`` handle returned
by ``Deployment.serve()``.

This module owns what ``launch/serve.py`` used to wire by hand (that
module now delegates here): the RRAM base is frozen (and drifted);
accuracy comes from the DoRA side-cars that were calibrated in SRAM.
``merge_magnitude`` (Algorithm 2 line 12) folds the DoRA column norms
once at serve-session creation so each decode matmul pays only the
low-rank epilogue.

Compiled step functions are built ONCE per ``(cfg, backend)`` and reused
across every request and session (``decode_step_fn`` / ``prefill_fn``).
The old code re-wrapped ``jax.jit`` around a fresh lambda on every
``prefill_and_cache``/``generate`` call, so each request retraced and
recompiled the whole decode stack; the registry below is the fix, and
``compile_count`` exposes the counter the regression tests pin down.
The continuous-batching engine over these steps lives in
``repro/deploy/engine.py``.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import substrate

BACKENDS = ("dequant", "codes", "codes_adc")


def backend_scope(backend: str, cfg=None, **options):
    """Context manager binding the substrate backend for trace time.

    EVERY backend binds explicitly — including ``dequant``. It used to
    return a nullcontext, which left the ambient default ("codes")
    active: the step registry below then keyed dequant and codes traces
    identically and both backends shared one jitted callable, doubling
    the warm compile count on the codes path. Binding makes the
    registry key (via ``substrate.active_backend_key``) honest.

    Substrate-aware scoping: passing the model config plumbs its
    ``RramConfig`` into the ADC-faithful backend automatically — the
    config is the single source of truth for ``code_max``/``adc_bits``,
    and an explicit option that CONFLICTS with it raises ``ValueError``
    (it used to be silently accepted, letting a session serve with an
    ADC the array was never programmed for). ``ServeSession`` always
    passes its deployment's config, so sessions never serve with a
    mismatched ADC. Extra ``options`` (e.g. ``accum="int8"``) forward
    to the backend's ``linear``.
    """
    if backend == "codes_adc" and cfg is not None:
        from repro.substrate.backends import resolve_adc_limits

        code_max, adc_bits = resolve_adc_limits(
            cfg.rram, options.get("code_max"), options.get("adc_bits")
        )
        options["code_max"] = code_max
        options["adc_bits"] = adc_bits
    return substrate.use_backend(backend, **options)


# ---------------------------------------------------------------------------
# compiled-step registry (the retrace fix)
# ---------------------------------------------------------------------------
#
# The substrate backend is read at TRACE time (substrate.use_backend), so
# a jitted step is only reusable under the backend it was traced with —
# the registry key is (cfg, active backend identity, mesh). The identity
# includes the backend OPTIONS, not just the name: ``accum="int8"`` and
# f32 trace to different programs under the same name. ``mesh`` is None
# for single-device steps; mesh-native steps wrap the same transformer
# body in ``shard_map`` and are keyed per mesh so an elastic re-mesh
# builds fresh steps without evicting the old mesh's. Shape variation
# within one entry (batch size, prompt length) is handled by jax.jit's
# own argument cache on the SAME callable, which is exactly what
# rebuilding the lambda per call threw away.

_STEP_REGISTRY: Dict[Tuple, "jax.stages.Wrapped"] = {}


def _registry_get(kind: str, cfg, build, mesh: Optional[Mesh] = None):
    key = (kind, cfg, substrate.active_backend_key(), mesh)
    fn = _STEP_REGISTRY.get(key)
    if fn is None:
        fn = _STEP_REGISTRY[key] = build()
    return fn


def decode_step_fn(cfg, mesh: Optional[Mesh] = None, params=None):
    """The jitted batched decode step for ``(cfg, active backend,
    mesh)``, built once and shared by every request, session, and the
    engine. ``pos`` is a (B,) vector of per-slot clocks (scalars
    broadcast).

    With a mesh, the same transformer body runs under ``shard_map``:
    params follow ``substrate.serve_param_specs`` (column-sharded
    prepared operands over the "model" axis, the DoRA epilogue psum
    inside the backend), tokens/cache/logits replicate. ``params`` (the
    session's sharded tree) is required then — the in_specs are derived
    from which leaves are actually wrapped."""
    from repro.models import transformer as T

    if mesh is None:
        return _registry_get(
            "decode", cfg,
            lambda: jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg)),
        )
    if params is None:
        raise ValueError("mesh decode steps derive in_specs from params")

    def build():
        specs = substrate.serve_param_specs(params)
        sm = shard_map(
            lambda p, c, t, i: T.decode_step(p, c, t, i, cfg),
            mesh=mesh,
            in_specs=(specs, P(), P(), P()),
            out_specs=(P(), P()),
            check_rep=False,
        )
        return jax.jit(sm)

    return _registry_get("decode", cfg, build, mesh=mesh)


def prefill_fn(cfg, mesh: Optional[Mesh] = None, params=None):
    """The jitted fused prefill for ``(cfg, active backend, mesh)``: one
    full-sequence forward returning (last logits, decode cache) —
    ``max_len`` is static (cache buffer extent). The mesh path is
    decoder-only (no enc_embeds)."""
    from repro.models import transformer as T

    if mesh is None:
        return _registry_get(
            "prefill", cfg,
            lambda: jax.jit(
                lambda p, t, max_len, e=None, pe=None: T.prefill(
                    p, t, cfg, max_len, e, pe
                ),
                static_argnums=(2,),
            ),
        )
    if params is None:
        raise ValueError("mesh prefill steps derive in_specs from params")

    def build():
        specs = substrate.serve_param_specs(params)

        def fn(p, t, max_len, e=None, pe=None):
            if e is not None or pe is not None:
                raise ValueError(
                    "mesh serving is decoder-only (no enc_embeds/patch_embeds)"
                )
            sm = shard_map(
                lambda p, t: T.prefill(p, t, cfg, max_len, None),
                mesh=mesh,
                in_specs=(specs, P()),
                out_specs=(P(), P()),
                check_rep=False,
            )
            return sm(p, t)

        return jax.jit(fn, static_argnums=(2,))

    return _registry_get("prefill", cfg, build, mesh=mesh)


def prefill_chunk_fn(cfg, mesh: Optional[Mesh] = None, params=None):
    """The jitted chunked-prefill step for ``(cfg, active backend,
    mesh)``: advance a live decode cache by one bucketed prompt chunk at
    per-slot positions. ``max_len``/``prefix`` are static; the chunk
    bucket width varies through jit's argument cache, which the engine
    bounds to a pow-2 set."""
    from repro.models import transformer as T

    if mesh is None:
        return _registry_get(
            "prefill_chunk", cfg,
            lambda: jax.jit(
                lambda p, t, c, pos0, nv, max_len, prefix=0: T.prefill_chunk(
                    p, t, c, pos0, nv, cfg, max_len, prefix
                ),
                static_argnums=(5, 6),
            ),
        )
    if params is None:
        raise ValueError("mesh chunk steps derive in_specs from params")

    def build():
        specs = substrate.serve_param_specs(params)

        def fn(p, t, c, pos0, nv, max_len, prefix=0):
            sm = shard_map(
                lambda p, t, c, pos0, nv: T.prefill_chunk(
                    p, t, c, pos0, nv, cfg, max_len, prefix
                ),
                mesh=mesh,
                in_specs=(specs, P(), P(), P(), P()),
                out_specs=(P(), P()),
                check_rep=False,
            )
            return sm(p, t, c, pos0, nv)

        return jax.jit(fn, static_argnums=(5, 6))

    return _registry_get("prefill_chunk", cfg, build, mesh=mesh)


def prefill_vision_fn(cfg, mesh: Optional[Mesh] = None):
    """The jitted vision-prefix admission step: scatter
    ``cfg.vision_tokens`` bidirectional patch positions into a fresh slot
    cache. One static shape per config — compiles exactly once."""
    from repro.models import transformer as T

    if mesh is not None:
        raise ValueError("mesh serving has no vision-prefix path")
    return _registry_get(
        "prefill_vision", cfg,
        lambda: jax.jit(
            lambda p, pe, c, max_len: T.prefill_vision(p, pe, c, cfg, max_len),
            static_argnums=(3,),
        ),
    )


def encode_fn(cfg, mesh: Optional[Mesh] = None):
    """The jitted encoder admission step: run the (bidirectional) encoder
    once and scatter every decoder layer's cross-attention K/V lines +
    ``enc_len`` into a slot cache."""
    from repro.models import transformer as T

    if mesh is not None:
        raise ValueError("mesh serving is decoder-only (no encoder)")
    return _registry_get(
        "encode", cfg,
        lambda: jax.jit(lambda p, c, e: T.encode_into_cache(p, c, e, cfg)),
    )


STEP_KINDS = ("decode", "prefill", "prefill_chunk", "prefill_vision", "encode")


def compile_count(cfg, mesh: Optional[Mesh] = None) -> int:
    """Total compiled-computation count across this (cfg, backend,
    mesh)'s step functions. Flat across repeated same-shape requests —
    the regression tests and ``benchmarks/serve_bench.py`` track it as
    the retrace counter."""
    total = 0
    for kind in STEP_KINDS:
        fn = _STEP_REGISTRY.get(
            (kind, cfg, substrate.active_backend_key(), mesh)
        )
        if fn is not None:
            # _cache_size is private jax API; the zero-recompile test's
            # `warm > 0` assertion is the canary if an upgrade drops it
            size = getattr(fn, "_cache_size", None)
            total += size() if callable(size) else 0
    return total


def prefill_and_cache(
    params, tokens, cfg, max_len: int, enc_embeds=None,
    mesh: Optional[Mesh] = None, patch_embeds=None,
):
    """Fused prefill: ONE full-sequence forward computes every layer's
    K/V (MLA latents, recurrent states) batched over the prompt and
    scatters them into the decode cache — replaces the old per-token
    ``decode_step`` Python loop (S sequential dispatches). Returns
    ``(last_logits (B,1,V), cache)``; parity with the step-by-step loop
    is pinned in tests/test_engine.py."""
    if cfg.encoder_layers and enc_embeds is None:
        raise ValueError("encoder-decoder config needs enc_embeds")
    return prefill_fn(cfg, mesh, params)(
        params, tokens, int(max_len), enc_embeds, patch_embeds
    )


def _next_token(logits, temperature: float, key):
    """Greedy or temperature sampling of the next token; returns
    (token, advanced key). EVERY position — including the first generated
    token — goes through this, so ``temperature > 0`` is honored from
    token 0 (the old serve loop argmax'd the first token regardless)."""
    if temperature > 0 and key is not None:
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        return tok.astype(jnp.int32), key
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32), key


def _check_sampling_args(temperature: float, key) -> None:
    """Surface intent mismatches instead of silently ignoring one of the
    two sampling knobs."""
    if temperature > 0 and key is None:
        raise ValueError("temperature > 0 needs a PRNG key")
    if temperature == 0 and key is not None:
        raise ValueError(
            "a PRNG key was passed but temperature == 0 samples greedily "
            "and would ignore it; pass temperature > 0 or drop the key"
        )


def generate(
    params, prompt: jax.Array, cfg, *, gen_len: int = 16,
    temperature: float = 0.0, enc_embeds=None, patch_embeds=None, key=None,
) -> Tuple[np.ndarray, float]:
    """Reference single-stream generation loop: fused prefill, then
    ``gen_len - 1`` decode steps (the first token comes from the prefill
    logits). Returns ``(tokens (B, gen_len), dt)`` where ``dt`` covers
    exactly those decode steps — so decode tok/s is
    ``B * (gen_len - 1) / dt``, with no prefill-sampled token smuggled
    into a decode-only timer. ``patch_embeds`` prepends a prefix-LM
    vision prefix; the decode clock then starts at ``P + S``. The
    continuous-batching path is ``repro.deploy.engine.ServeEngine``."""
    _check_sampling_args(temperature, key)
    if gen_len < 1:
        raise ValueError(f"gen_len must be >= 1, got {gen_len}")
    b, s = prompt.shape
    prefix = 0 if patch_embeds is None else patch_embeds.shape[1]
    max_len = prefix + s + gen_len
    logits, cache = prefill_and_cache(
        params, prompt, cfg, max_len, enc_embeds, patch_embeds=patch_embeds
    )
    step = decode_step_fn(cfg)
    tok, key = _next_token(logits, temperature, key)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(gen_len - 1):
        logits, cache = step(
            params, cache, tok, jnp.full((b,), prefix + s + i, jnp.int32)
        )
        tok, key = _next_token(logits, temperature, key)
        out.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    return np.concatenate(out, axis=1), dt


class ServeSession:
    """A deployment bound for serving: adapters merged (Algorithm 2 line
    12), substrate backend scope applied around every call.

    Obtained from ``Deployment.serve()``; holds ``params`` in the exact
    ``{"base", "adapters"}`` layout the transformer forward consumes, so
    custom serving loops can also reach in directly (inside
    ``session.scope()``)."""

    def __init__(
        self, deployment, params, options: Optional[dict] = None,
        mesh: Optional[Mesh] = None,
    ):
        self.deployment = deployment
        # the unwrapped single-device tree is kept as the re-mesh source:
        # elastic degradation re-wraps + re-places it on the new mesh
        self._host_params = params
        self.params = params
        self.options = dict(options or {})
        self.mesh = None
        self.shard_stats: Optional[dict] = None
        self._auto_key_calls = 0
        if mesh is not None:
            self.reshard(mesh)

    def reshard(self, mesh: Optional[Mesh]):
        """(Re)bind this session to ``mesh``: wrap every column-shardable
        prepared leaf (``substrate.shard_prepared_for_serve``) and place
        the tree with ``NamedSharding``. ``None`` returns the session to
        the single-device tree. Step functions for the new mesh build
        lazily on first use (the registry keys on mesh)."""
        if mesh is None:
            self.mesh = None
            self.params = self._host_params
            self.shard_stats = None
            return self
        if self.backend != "codes":
            raise ValueError(
                f"mesh serving runs the prepared codes fast path; "
                f"backend={self.backend!r} is single-device"
            )
        wrapped, stats = substrate.shard_prepared_for_serve(
            self._host_params, mesh
        )
        self.params = substrate.place_serve_params(wrapped, mesh)
        self.mesh = mesh
        self.shard_stats = stats
        return self

    def decode_step(self):
        """This session's jitted decode step (mesh-aware). Call inside
        ``scope()``."""
        return decode_step_fn(self.cfg, self.mesh, self.params)

    @property
    def cfg(self):
        return self.deployment.cfg

    @property
    def backend(self) -> str:
        return self.deployment.backend

    def scope(self):
        """The substrate backend scope for this session (RramConfig
        options plumbed automatically, plus any serve-time options like
        ``accum="int8"``). Wrap any custom trace in it."""
        return backend_scope(self.backend, self.cfg, **self.options)

    def _sampling_key(self, temperature: float, key):
        """Derive a sampling key from the deployment key when the caller
        asks for temperature sampling without providing one (it used to
        silently fall back to greedy); reject a key with temperature 0."""
        if temperature > 0 and key is None:
            self._auto_key_calls += 1
            key = jax.random.fold_in(
                self.deployment.program_key, self._auto_key_calls
            )
        _check_sampling_args(temperature, key)
        return key

    def prefill(self, tokens, max_len: int, enc_embeds=None, patch_embeds=None):
        with self.scope():
            return prefill_and_cache(
                self.params, tokens, self.cfg, max_len, enc_embeds,
                mesh=self.mesh, patch_embeds=patch_embeds,
            )

    def generate(
        self, prompt, *, gen_len: int = 16, temperature: float = 0.0,
        enc_embeds=None, patch_embeds=None, key=None,
    ) -> Tuple[np.ndarray, float]:
        """Single-call generation: each prompt row becomes one request on
        a throwaway continuous-batching engine (all admitted at tick 0),
        so this shares the compiled steps and slot bookkeeping with the
        production serving path — including encoder-decoder requests
        (per-slot cross-attention cache lines) and vision-prefix requests
        (``patch_embeds`` (B, P, d))."""
        key = self._sampling_key(temperature, key)
        if self.mesh is not None and (
            enc_embeds is not None or patch_embeds is not None
        ):
            raise ValueError("mesh serving is decoder-only")
        from repro.deploy.engine import ServeEngine

        b, s = prompt.shape
        prefix = 0 if patch_embeds is None else patch_embeds.shape[1]
        src_len = 0 if enc_embeds is None else enc_embeds.shape[1]
        engine = ServeEngine(
            self, max_slots=b, max_len=prefix + s + gen_len, src_len=src_len
        )
        reqs = [
            engine.submit(
                prompt[i], max_new=gen_len, temperature=temperature,
                key=None if key is None else jax.random.fold_in(key, i),
                enc_embeds=None if enc_embeds is None else enc_embeds[i],
                patch_embeds=None if patch_embeds is None else patch_embeds[i],
            )
            for i in range(b)
        ]
        engine.run()
        dt = engine.decode_seconds
        toks = np.stack([np.asarray(r.tokens, np.int32) for r in reqs])
        return toks, dt

    def describe(self) -> str:
        """Startup log line: resident RRAM bytes, SRAM side-car bytes and
        the calibrated-parameter fraction (paper's 2.34% headline)."""
        from repro.core.calibrate import (
            calibrated_fraction, rram_bytes, sram_bytes,
        )

        # byte accounting reads the deployment's true trees, not
        # self.params: serve-time prepared params are padded/fused
        # serving artifacts and would inflate the resident counts
        dep = self.deployment
        kind = "measured resident" if self.backend != "dequant" else "estimated"
        frac = calibrated_fraction(dep.base, dep.adapters)
        return (
            f"backend={self.backend} rram_bytes={rram_bytes(dep.base)}"
            f" ({kind}) sram_bytes={sram_bytes(dep.adapters)}"
            f" calibrated_params={frac:.2%}"
        )
