"""Serving half of the deployment lifecycle: backend scoping, the
prefill/decode loops, and the ``ServeSession`` handle returned by
``Deployment.serve()``.

This module owns what ``launch/serve.py`` used to wire by hand (that
module now delegates here): the RRAM base is frozen (and drifted);
accuracy comes from the DoRA side-cars that were calibrated in SRAM.
``merge_magnitude`` (Algorithm 2 line 12) folds the DoRA column norms
once at serve-session creation so each decode matmul pays only the
low-rank epilogue.
"""
from __future__ import annotations

import contextlib
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import substrate

BACKENDS = ("dequant", "codes", "codes_adc")


def backend_scope(backend: str, cfg=None):
    """Context manager binding the substrate backend for trace time.

    Substrate-aware scoping: passing the model config plumbs its
    ``RramConfig`` into the ADC-faithful backend automatically
    (``code_max``/``adc_bits`` must match the programmed deployment —
    ``ServeSession`` always passes its deployment's config, so sessions
    never serve with a mismatched ADC).
    """
    if backend == "dequant":
        return contextlib.nullcontext()
    if backend == "codes_adc" and cfg is not None:
        return substrate.use_backend(
            backend, code_max=cfg.rram.code_max, adc_bits=cfg.rram.adc_bits
        )
    return substrate.use_backend(backend)


def prefill_and_cache(params, tokens, cfg, max_len: int, enc_embeds=None):
    """Run the prompt through the model step-by-step to build the cache.

    (A fused full-sequence prefill that scatters into the cache is the
    perf path on TPU; the loop keeps serving logic simple on CPU and is
    identical in semantics.)
    """
    from repro.models import transformer as T

    b, s = tokens.shape
    src_len = enc_embeds.shape[1] if enc_embeds is not None else 0
    cache = T.init_cache(cfg, b, max_len, src_len=src_len)
    if cfg.encoder_layers:
        cache["enc_out"] = T.encode(
            params["base"], params["adapters"], enc_embeds, cfg
        )
    logits = None
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    for i in range(s):
        logits, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
    return logits, cache


def _next_token(logits, temperature: float, key):
    """Greedy or temperature sampling of the next token; returns
    (token, advanced key). EVERY position — including the first generated
    token — goes through this, so ``temperature > 0`` is honored from
    token 0 (the old serve loop argmax'd the first token regardless)."""
    if temperature > 0 and key is not None:
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        return tok.astype(jnp.int32), key
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32), key


def generate(
    params, prompt: jax.Array, cfg, *, gen_len: int = 16,
    temperature: float = 0.0, enc_embeds=None, key=None,
) -> Tuple[np.ndarray, float]:
    from repro.models import transformer as T

    b, s = prompt.shape
    max_len = s + gen_len
    logits, cache = prefill_and_cache(params, prompt, cfg, max_len, enc_embeds)
    out = []
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    tok, key = _next_token(logits, temperature, key)
    t0 = time.perf_counter()
    for i in range(gen_len):
        out.append(np.asarray(tok))
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok, key = _next_token(logits, temperature, key)
    dt = time.perf_counter() - t0
    return np.concatenate(out, axis=1), dt


class ServeSession:
    """A deployment bound for serving: adapters merged (Algorithm 2 line
    12), substrate backend scope applied around every call.

    Obtained from ``Deployment.serve()``; holds ``params`` in the exact
    ``{"base", "adapters"}`` layout the transformer forward consumes, so
    custom serving loops can also reach in directly (inside
    ``session.scope()``)."""

    def __init__(self, deployment, params):
        self.deployment = deployment
        self.params = params

    @property
    def cfg(self):
        return self.deployment.cfg

    @property
    def backend(self) -> str:
        return self.deployment.backend

    def scope(self):
        """The substrate backend scope for this session (RramConfig
        options plumbed automatically). Wrap any custom trace in it."""
        return backend_scope(self.backend, self.cfg)

    def prefill(self, tokens, max_len: int, enc_embeds=None):
        with self.scope():
            return prefill_and_cache(
                self.params, tokens, self.cfg, max_len, enc_embeds
            )

    def generate(
        self, prompt, *, gen_len: int = 16, temperature: float = 0.0,
        enc_embeds=None, key=None,
    ) -> Tuple[np.ndarray, float]:
        with self.scope():
            return generate(
                self.params, prompt, self.cfg, gen_len=gen_len,
                temperature=temperature, enc_embeds=enc_embeds, key=key,
            )

    def describe(self) -> str:
        """Startup log line: resident RRAM bytes, SRAM side-car bytes and
        the calibrated-parameter fraction (paper's 2.34% headline)."""
        from repro.core.calibrate import (
            calibrated_fraction, rram_bytes, sram_bytes,
        )

        kind = "measured resident" if self.backend != "dequant" else "estimated"
        frac = calibrated_fraction(self.params["base"], self.params["adapters"])
        return (
            f"backend={self.backend} rram_bytes={rram_bytes(self.params['base'])}"
            f" ({kind}) sram_bytes={sram_bytes(self.params['adapters'])}"
            f" calibrated_params={frac:.2%}"
        )
