"""``Deployment`` — the single lifecycle object from programming to
drift-aware serving.

The paper's device-lifetime story as first-class operations:

* ``Deployment.program(cfg, key, backend=...)`` — the programming event.
  Owns the resident base (uint8 conductance codes for every RRAM leaf;
  read back to floats for the ``dequant`` backend), the ``RramConfig``
  and the substrate backend binding.
* ``dep.advance(hours)`` — the drift clock: time passes in the field and
  the resident codes re-drift (``rram.apply_drift`` with the log-time
  sigma), WITHOUT reprogramming. Deterministic per event index and keyed
  off the deployment key, so any drift history replays exactly.
* ``dep.calibrate(batch_or_samples)`` — feature-KD calibration of the
  SRAM side-cars (teacher-feature caching + ``CalibState`` + the jitted
  step loop); returns a ``CalibrationReport``. The array is never
  written.
* ``dep.serve()`` — a ``ServeSession`` with the DoRA magnitudes merged
  (Algorithm 2 line 12) and the backend scope bound.
* ``dep.snapshot()`` / ``Deployment.restore()`` — persistence through
  ``checkpoint.CheckpointManager``: adapters + optimizer + the lifecycle
  record (keys + drift history). The multi-GB base is never stored — it
  is re-derived by replaying program + drift events.

Because drift can now happen repeatedly, the multi-drift-epoch scenario
(program -> advance -> calibrate -> advance -> recalibrate -> serve) is a
plain sequence of method calls — the one-shot free-function API could
not represent it.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import substrate
from repro.checkpoint.manager import CheckpointManager
from repro.core import rram
from repro.core.calibrate import (
    CalibState,
    calibrated_fraction,
    drift_model,
    make_cached_calib_step,
    make_calib_step,
    merge_adapters_for_serve,
    program_model,
    rram_bytes,
    sram_bytes,
    teacher_features,
)
from repro.data.pipeline import DataConfig, global_batch_at_step
from repro.deploy import serving
from repro.models import transformer as T
from repro.optim.adam import AdamW, adamw_init

Pytree = Any

_DEPLOYMENT_META = "deployment.json"


def _key_pair(key) -> Tuple[jax.Array, jax.Array]:
    """(teacher_init_key, programming_key) from an int seed, a PRNGKey,
    or an explicit pair. Int seeds use (PRNGKey(s), PRNGKey(s+1)) — the
    exact keying of the legacy ``serve.load_student`` path, which is what
    makes shim-vs-Deployment parity bitwise."""
    if isinstance(key, (tuple, list)):
        tk, pk = key
        return jnp.asarray(tk), jnp.asarray(pk)
    if isinstance(key, (int, np.integer)):
        return jax.random.PRNGKey(key), jax.random.PRNGKey(key + 1)
    key = jnp.asarray(key)
    return key, jax.random.fold_in(key, 1)


def _dequant_like(codes: Pytree, like: Pytree) -> Pytree:
    """Read a codes-resident tree back to floats, leaf dtypes taken from
    ``like`` (the pre-programming base). Bitwise identical to
    ``program_model(mode='dequant')`` for the same keys — it is the same
    ``dequantize`` applied to the same codes. Non-RRAM leaves pass
    through as the SAME buffers (teacher/student share peripherals)."""

    def leaf(c, w):
        if isinstance(c, rram.CrossbarWeight):
            return rram.dequantize(c, dtype=w.dtype)
        return c

    return jax.tree_util.tree_map(
        leaf, codes, like,
        is_leaf=lambda n: isinstance(n, rram.CrossbarWeight),
    )


def _device_batch(np_batch: Dict) -> Dict:
    return {
        k: jnp.asarray(v, jnp.bfloat16 if v.dtype == np.float32 else None)
        for k, v in np_batch.items()
    }


@dataclasses.dataclass
class CalibrationReport:
    """Outcome of one ``Deployment.calibrate`` call."""

    losses: List[float]          # per-step feature MSE (Algorithm 1 loss)
    epochs_run: int
    sram_bytes: int              # resident side-car bytes (digital SRAM)
    rram_bytes: int              # resident base bytes (analog array)
    base_params: int
    adapter_params: int
    calibrated_fraction: float   # paper's 2.34% headline
    backend: str
    drift_events: int            # drift-clock ticks seen before this calib

    @property
    def initial_loss(self) -> float:
        return self.losses[0]

    @property
    def final_loss(self) -> float:
        return self.losses[-1]

    def summary(self) -> str:
        return (
            f"calibrated {self.epochs_run} epochs: feature MSE "
            f"{self.initial_loss:.6f} -> {self.final_loss:.6f} | "
            f"sram_bytes={self.sram_bytes} "
            f"({self.calibrated_fraction:.2%} of params) "
            f"rram_bytes={self.rram_bytes} backend={self.backend}"
        )


class Deployment:
    """One RRAM deployment over its lifetime. See module docstring.

    The resident uint8 codes (``self.codes``) are the ground truth for
    the array state; ``self.base`` is what forwards consume — the codes
    themselves under ``codes``/``codes_adc`` backends, or the float
    read-back under ``dequant``. ``advance`` mutates only the codes (and
    refreshes the read-back); ``calibrate`` mutates only the adapters.
    """

    def __init__(
        self, cfg, backend: str, teacher_base: Pytree, codes: Pytree,
        adapters: Pytree, teacher_key: jax.Array, program_key: jax.Array,
    ):
        if backend not in serving.BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; available: {serving.BACKENDS}"
            )
        self.cfg = cfg
        self.backend = backend
        self.teacher_base = teacher_base
        self.codes = codes
        self.adapters = adapters
        self.teacher_key = teacher_key
        self.program_key = program_key
        self.opt_state: Optional[Pytree] = None
        self.step: int = 0
        self.drift_hours: List[float] = []
        self._refresh_base()

    # -- programming event --------------------------------------------------

    @classmethod
    def program(
        cls, cfg, key=0, *, backend: str = "dequant",
        adapters: Optional[Pytree] = None,
    ) -> "Deployment":
        """The deployment event: init the teacher, program every RRAM
        leaf onto the simulated crossbar (one programming event, incl.
        programming-time drift), and bind the substrate backend.

        ``key`` is an int seed, a PRNGKey, or a ``(teacher_key,
        program_key)`` pair. ``adapters`` seeds the SRAM side-cars
        (default: fresh DoRA adapters from the teacher init)."""
        teacher_key, program_key = _key_pair(key)
        params = T.init_params(teacher_key, cfg)
        codes = program_model(params["base"], cfg.rram, program_key, mode="codes")
        return cls(
            cfg=cfg, backend=backend, teacher_base=params["base"], codes=codes,
            adapters=params["adapters"] if adapters is None else adapters,
            teacher_key=teacher_key, program_key=program_key,
        )

    def _refresh_base(self):
        if self.backend == "dequant":
            self.base = _dequant_like(self.codes, self.teacher_base)
        else:
            self.base = self.codes

    # -- drift clock --------------------------------------------------------

    @property
    def field_hours(self) -> float:
        """Total field time elapsed on the drift clock."""
        return float(sum(self.drift_hours))

    def advance(self, hours: float) -> "Deployment":
        """Let ``hours`` of field time pass: the resident codes re-drift
        (log-time relaxation; each tick draws the variance increment over
        the cumulative clock, so tick granularity doesn't change the
        total drift) without any reprogramming. Event ``i`` draws from
        ``fold_in(leaf_key, i)`` — deterministic, order-sensitive, and
        exactly replayable from ``(program_key, drift_hours)``."""
        self.codes = drift_model(
            self.codes, self.cfg.rram, self.program_key,
            hours=hours, event_index=len(self.drift_hours),
            clock_offset=self.field_hours,
        )
        self.drift_hours.append(float(hours))
        self._refresh_base()
        return self

    # -- calibration --------------------------------------------------------

    def calib_state(self) -> CalibState:
        """The whole-model calibration state over this deployment's
        resident base (used directly by the production train driver;
        ``adopt`` syncs the result back)."""
        if self.opt_state is None:
            self.opt_state = adamw_init(self.adapters)
        return CalibState(
            self.teacher_base, self.base, self.adapters, self.opt_state,
            jnp.asarray(self.step, jnp.int32),
        )

    def adopt(self, state: CalibState) -> "Deployment":
        """Sync adapters/optimizer/step back from an externally-run
        ``CalibState`` loop (launch/train.py's mesh/checkpoint loop)."""
        self.adapters = state.adapters
        self.opt_state = state.opt_state
        self.step = int(state.step)
        return self

    def _calibration_batch(self, batch_or_samples, seq_len: int) -> Dict:
        if isinstance(batch_or_samples, dict):
            return batch_or_samples
        n = int(batch_or_samples)
        cfg = self.cfg
        dcfg = DataConfig(
            vocab=cfg.vocab, seq_len=seq_len, global_batch=n,
            n_calibration_samples=n,
            enc_src_len=seq_len if cfg.encoder_layers else 0,
            d_model=cfg.d_model if (cfg.encoder_layers or cfg.vision_tokens)
            else 0,
            vision_tokens=cfg.vision_tokens,
        )
        return _device_batch(global_batch_at_step(dcfg, 0))

    def calibrate(
        self, batch_or_samples: Union[Dict, int] = 10, *,
        steps: int = 20, lr: float = 1e-3, opt: Optional[AdamW] = None,
        seq_len: int = 32, cached_teacher: Optional[bool] = None,
        loss_threshold: float = 0.0,
    ) -> CalibrationReport:
        """Algorithm 1 over the whole model: train ONLY the SRAM
        side-cars against the frozen teacher, on the current (possibly
        multiply-drifted) resident base. ``batch_or_samples`` is a batch
        dict or a calibration-set size (paper: 10 samples, generated
        deterministically). Teacher features are cached once per call
        where supported (single-stack decoders); codes-resident bases
        execute through the differentiable ``dequant`` backend — the
        codes stay frozen either way."""
        import contextlib

        cfg = self.cfg
        opt = opt if opt is not None else AdamW(lr=lr)
        batch = self._calibration_batch(batch_or_samples, seq_len)
        cacheable = not cfg.encoder_layers and not cfg.vision_tokens
        use_cached = cacheable if cached_teacher is None else (
            cached_teacher and cacheable
        )
        state = self.calib_state()
        backend_ctx = (
            substrate.use_backend("dequant")
            if self.backend != "dequant" else contextlib.nullcontext()
        )
        losses: List[float] = []
        with backend_ctx:
            if use_cached:
                feats = teacher_features(self.teacher_base, batch, cfg)
                step_fn = jax.jit(make_cached_calib_step(cfg, opt))
                run = lambda s: step_fn(s, feats, batch)
            else:
                step_fn = jax.jit(make_calib_step(cfg, opt))
                run = lambda s: step_fn(s, batch)
            for _ in range(steps):
                state, metrics = run(state)
                losses.append(float(metrics["loss"]))
                if loss_threshold and losses[-1] <= loss_threshold:
                    break
        self.adopt(state)
        n_base, n_adapters = T.count_params(
            {"base": self.base, "adapters": self.adapters}
        )
        return CalibrationReport(
            losses=losses, epochs_run=len(losses),
            sram_bytes=sram_bytes(self.adapters),
            rram_bytes=rram_bytes(self.base),
            base_params=n_base, adapter_params=n_adapters,
            calibrated_fraction=n_adapters / max(n_base, 1),
            backend=self.backend, drift_events=len(self.drift_hours),
        )

    # -- serving ------------------------------------------------------------

    def serve(self) -> serving.ServeSession:
        """Bind for serving: merge the DoRA magnitudes (Algorithm 2 line
        12 — no per-step norm recompute) and return a session with the
        substrate backend scope attached."""
        merged = merge_adapters_for_serve(self.base, self.adapters)
        return serving.ServeSession(
            self, {"base": self.base, "adapters": merged}
        )

    # -- introspection ------------------------------------------------------

    def rram_bytes(self) -> int:
        return rram_bytes(self.base)

    def sram_bytes(self) -> int:
        return sram_bytes(self.adapters)

    def calibrated_fraction(self) -> float:
        return calibrated_fraction(self.base, self.adapters)

    def _teacher_logits(self, batch: Dict) -> jax.Array:
        # The teacher is frozen, so repeated logit_mse calls on the same
        # batch (quickstart tracks the gap across the whole lifecycle)
        # reuse one forward; the cache holds the batch leaves so object
        # identity is a sound key.
        leaves = tuple(jax.tree_util.tree_leaves(batch))
        cached = getattr(self, "_teacher_logits_cache", None)
        if cached is not None and len(cached[0]) == len(leaves) and all(
            a is b for a, b in zip(cached[0], leaves)
        ):
            return cached[1]
        t = T.forward(
            {"base": self.teacher_base, "adapters": {}}, batch, self.cfg,
            use_adapters=False,
        ).astype(jnp.float32)
        self._teacher_logits_cache = (leaves, t)
        return t

    def logit_mse(self, batch: Dict, *, use_adapters: bool = True) -> float:
        """Teacher/student logit MSE on ``batch`` — the drift-degradation
        / calibration-recovery metric the examples report."""
        t = self._teacher_logits(batch)
        with serving.backend_scope(self.backend, self.cfg):
            s = T.forward(
                {"base": self.base,
                 "adapters": self.adapters if use_adapters else {}},
                batch, self.cfg, use_adapters=use_adapters,
            ).astype(jnp.float32)
        return float(jnp.mean((t - s) ** 2))

    # -- persistence --------------------------------------------------------

    def snapshot(
        self, directory_or_manager, *, blocking: bool = True
    ) -> int:
        """Checkpoint the mutable lifecycle state through
        ``CheckpointManager`` (atomic, retained, optionally async — the
        same path ``runtime/fault.PreemptionGuard`` shutdowns use):
        adapters + optimizer + the lifecycle record (keys, drift
        history). The base is NOT stored — restore re-derives it by
        replaying the programming event and every drift tick."""
        manager = (
            directory_or_manager
            if isinstance(directory_or_manager, CheckpointManager)
            else CheckpointManager(str(directory_or_manager))
        )
        if self.opt_state is None:
            self.opt_state = adamw_init(self.adapters)
        step = int(self.step)
        lifecycle = {
            "teacher_key": np.asarray(self.teacher_key),
            "program_key": np.asarray(self.program_key),
            "drift_hours": np.asarray(self.drift_hours, np.float64),
        }
        manager.save(
            step,
            {"adapters": self.adapters, "opt": self.opt_state,
             "lifecycle": lifecycle},
            blocking=blocking,
        )
        meta = {
            "format": 1, "backend": self.backend,
            "arch": getattr(self.cfg, "name", None),
            "drift_events": len(self.drift_hours),
        }
        with open(os.path.join(manager.directory, _DEPLOYMENT_META), "w") as f:
            json.dump(meta, f)
        return step

    @classmethod
    def restore(
        cls, cfg, directory, *, step: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> "Deployment":
        """Rebuild a deployment from a snapshot directory: re-program
        from the recorded keys, replay the drift history tick-by-tick
        (deterministic — the restored codes are bitwise the codes at
        snapshot time), then load adapters + optimizer. ``backend``
        overrides the recorded binding (e.g. restore a dequant-trained
        deployment straight onto the fused codes path)."""
        manager = CheckpointManager(str(directory))
        if step is None:
            step = manager.latest_step()
        if step is None:
            raise FileNotFoundError(f"no snapshots in {directory}")
        meta_path = os.path.join(manager.directory, _DEPLOYMENT_META)
        meta = {}
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                meta = json.load(f)
        backend = backend or meta.get("backend", "dequant")
        life = manager.restore(
            step,
            {"lifecycle": {
                "teacher_key": np.zeros((2,), np.uint32),
                "program_key": np.zeros((2,), np.uint32),
                "drift_hours": np.zeros((0,), np.float64),
            }},
        )["lifecycle"]
        dep = cls.program(
            cfg, (life["teacher_key"], life["program_key"]), backend=backend
        )
        for hours in np.asarray(life["drift_hours"]).tolist():
            dep.advance(hours)
        restored = manager.restore(
            step, {"adapters": dep.adapters, "opt": adamw_init(dep.adapters)}
        )
        dep.adapters = restored["adapters"]
        dep.opt_state = restored["opt"]
        dep.step = int(step)
        return dep


# ---------------------------------------------------------------------------
# Abstract (eval_shape) views — the dry-run/compile planner builds its
# sharded CalibState and merged-adapter serve params from these, so the
# planning path and the live path construct deployments the same way.
# ---------------------------------------------------------------------------


def abstract_params(cfg) -> Pytree:
    return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def abstract_calib_state(cfg, params_abs: Optional[Pytree] = None) -> CalibState:
    params_abs = abstract_params(cfg) if params_abs is None else params_abs
    opt_abs = jax.eval_shape(adamw_init, params_abs["adapters"])
    return CalibState(
        params_abs["base"], params_abs["base"], params_abs["adapters"],
        opt_abs, jax.ShapeDtypeStruct((), jnp.int32),
    )


def abstract_serve_params(cfg, params_abs: Optional[Pytree] = None) -> Dict:
    params_abs = abstract_params(cfg) if params_abs is None else params_abs
    merged_abs = jax.eval_shape(
        merge_adapters_for_serve, params_abs["base"], params_abs["adapters"]
    )
    return {"base": params_abs["base"], "adapters": merged_abs}
