"""Continuous-batching serve engine over the vectorized decode step.

The paper's deployment story ends here: the RRAM base is frozen, the
DoRA side-cars are merged into the kernel epilogue, and every decoded
token pays one crossbar matmul plus the low-rank epilogue. What this
module adds is the traffic shape of that story — many concurrent
requests with ragged prompts, arriving and finishing at different times,
all advanced by ONE compiled batched ``decode_step``:

* **Slots.** A fixed ``(max_slots, max_len)`` decode cache is allocated
  once. Each in-flight request owns one slot (one batch row); finished
  slots are recycled for queued requests.
* **Per-slot clocks.** ``pos`` is a ``(B,)`` int32 vector — every slot
  sits at its own sequence offset, so ragged prompt lengths and
  mid-stream admission need no padding or lockstep restarts.
* **Chunked admission.** ``submit()`` splits the prompt into fixed-size
  chunks (pow-2 bucketed, masked tail) and advances ONE chunk per engine
  tick interleaved with the decode step, so a long prompt never stalls
  in-flight slots — and the jit cache sees a bounded set of chunk widths
  instead of one program per prompt length. Attention-stack configs
  chunk; SSM/RG-LRU recurrences (``associative_scan`` regrouping is
  length-dependent) keep the fused exact-length prefill. The first token
  is sampled from the last chunk's logits (TTFT recorded per request),
  then the batch-1 cache is scattered into the slot's row
  (``transformer.write_cache_slot``).
* **Encoder-decoder slots.** seamless-style requests carry
  ``enc_embeds``: admission runs the encoder once and freezes per-layer
  cross-attention K/V lines into the slot ("xk"/"xv"), masked per slot
  by ``enc_len`` — decode ticks never touch the encoder again.
* **Vision-prefix slots.** paligemma-style requests carry
  ``patch_embeds``: the ``cfg.vision_tokens`` patch positions are
  prefilled bidirectionally (prefix-LM) ahead of the text chunks, and
  the slot's clock starts at ``P + prompt_len``.
* **Shared prefix cache.** Admission snapshots the batch-1 cache at
  every chunk boundary, keyed by a token-hash chain (seeded with the
  encoder/vision bytes). A later request with the same prefix resumes
  from the snapshot — copy-on-admit, bitwise-identical to a cold
  admission because the snapshot IS the cold computation's intermediate
  state — skipping the shared prompt's prefill entirely (lower TTFT).
* **One jitted step for everyone.** ``step()`` advances ALL active slots
  with a single ``decode_step_fn(cfg)`` call — compiled once per
  ``(cfg, backend)`` in ``deploy.serving`` and reused across requests,
  sessions, and engines (the retrace fix). Inactive slots ride along as
  dead rows: their writes land in recycled cache lines that the per-slot
  validity masks keep invisible to live requests.
* **Unified retirement.** Every request — including one whose FIRST
  token is EOS — retires through ``_finish``; ``first_tokens``,
  ``decode_tokens`` and ``completed`` always satisfy
  ``generated_tokens == first_tokens + decode_tokens`` (asserted in
  tests/test_engine.py).

Determinism: every row of the batched step computes exactly what a
single-request ``serving.generate`` call computes (row-independent
kernels + per-slot masks + exact-zero masked softmax tails), so engine
output is bitwise-identical to N independent ``generate`` calls —
tests/test_engine.py pins this on the ``dequant`` and ``codes``
backends, ragged + staggered, for every mixer family including
cross-attention and vision-prefix configs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from typing import Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.deploy import serving

# mixers that support chunked prefill against a live decode cache
_CHUNKABLE = ("attn", "local", "swa")


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle record."""

    rid: int
    prompt: np.ndarray               # (s,) int32
    max_new: int
    temperature: float = 0.0
    key: Optional[jax.Array] = None  # advanced as the request samples
    eos_id: Optional[int] = None
    enc_embeds: Optional[np.ndarray] = None    # (s_src, d) [enc-dec]
    patch_embeds: Optional[np.ndarray] = None  # (P, d) [vision prefix]
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None       # None while queued / after retiring
    admitted_tick: Optional[int] = None
    submitted_at: Optional[float] = None  # perf_counter at submit()
    ttft_seconds: Optional[float] = None  # submit -> first token (incl. queue wait)
    prefix_hit_tokens: int = 0       # prompt tokens reused from the prefix cache
    # admission progress (engine-internal, per-slot batch-1 state)
    _cache: Optional[dict] = dataclasses.field(default=None, repr=False)
    _logits: Optional[jax.Array] = dataclasses.field(default=None, repr=False)
    _chain: Optional[list] = dataclasses.field(default=None, repr=False)
    _spans: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list, repr=False
    )
    _vision_pending: bool = dataclasses.field(default=False, repr=False)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def vision_len(self) -> int:
        return 0 if self.patch_embeds is None else int(self.patch_embeds.shape[0])


class ServeEngine:
    """Slot-based continuous-batching scheduler over a ``ServeSession``.

    ``submit()`` admits (or queues) a request; ``step()`` advances every
    admitting slot by one prefill chunk and every active slot by one
    token; ``run()`` drains the queue. Serves every zoo config:
    decoder-only, encoder-decoder (``src_len`` bounds the encoder
    extent), and vision-prefix.
    """

    def __init__(
        self, session, *, max_slots: int = 4, max_len: int = 128,
        src_len: int = 0, prefill_chunk: int = 32, min_bucket: int = 8,
        prefix_cache_entries: int = 16,
    ):
        from repro.models import transformer as T

        self.session = session
        self.cfg = session.cfg
        if self.cfg.encoder_layers and src_len <= 0:
            raise ValueError(
                "encoder-decoder engine needs src_len > 0 (the cross-"
                "attention cache extent; requests may be shorter)"
            )
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.src_len = int(src_len)
        self.prefill_chunk = _pow2_ceil(int(prefill_chunk))
        self.min_bucket = min(_pow2_ceil(int(min_bucket)), self.prefill_chunk)
        self.chunked = all(
            m in _CHUNKABLE for m in self.cfg.mixer_pattern
        )
        self.prefix_cache_entries = int(prefix_cache_entries)
        # chunk-boundary snapshots: hash-chain digest -> (tokens, cache,
        # logits). LRU-capped; lives per engine (cfg+backend+extent fixed).
        self._prefix_cache: "OrderedDict[bytes, tuple]" = OrderedDict()
        with session.scope():
            self.cache = T.init_cache(
                self.cfg, self.max_slots, self.max_len, src_len=self.src_len
            )
        # per-slot clocks / occupancy (host-side scheduler state)
        self.pos = np.zeros(self.max_slots, np.int32)
        self.active = np.zeros(self.max_slots, bool)
        self.last_tok = np.zeros((self.max_slots, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * self.max_slots
        self.pending: Deque[Request] = deque()
        self.tick = 0
        self.decode_seconds = 0.0   # time inside batched decode steps
        self.decode_tokens = 0      # tokens produced by those steps
        self.first_tokens = 0       # tokens sampled from prefill logits
        self.completed = 0          # requests retired (any reason)
        self.prefill_chunks = 0     # chunk/vision admission units run
        self.prefix_lookups = 0
        self.prefix_hits = 0         # full-prompt snapshot hits
        self.prefix_partial_hits = 0  # shared-prefix (partial) hits
        self._next_rid = 0

    @property
    def generated_tokens(self) -> int:
        """Every token handed to a requester, first tokens included."""
        return self.first_tokens + self.decode_tokens

    # -- admission -----------------------------------------------------------

    def submit(
        self, prompt, *, max_new: int = 16, temperature: float = 0.0,
        key: Optional[jax.Array] = None, eos_id: Optional[int] = None,
        enc_embeds=None, patch_embeds=None,
    ) -> Request:
        """Enqueue a request; admission starts immediately if a slot is
        free (a single-chunk prompt gets its first token before this
        returns; longer prompts advance one chunk per ``step()``).
        ``prompt`` is a (s,) or (1, s) int token array; ``enc_embeds``
        (s_src, d) for encoder-decoder configs, ``patch_embeds`` (P, d)
        for vision-prefix configs (leading batch dim of 1 accepted)."""
        serving._check_sampling_args(temperature, key)
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        cfg = self.cfg
        if cfg.encoder_layers:
            if self.session.mesh is not None:
                raise ValueError("mesh serving is decoder-only (no encoder)")
            if enc_embeds is None:
                raise ValueError("encoder-decoder request needs enc_embeds")
            enc_embeds = np.asarray(enc_embeds)
            if enc_embeds.ndim == 3:
                enc_embeds = enc_embeds[0]
            if enc_embeds.shape[0] > self.src_len:
                raise ValueError(
                    f"enc_embeds length {enc_embeds.shape[0]} exceeds engine "
                    f"src_len ({self.src_len})"
                )
        elif enc_embeds is not None:
            raise ValueError("enc_embeds passed to a decoder-only config")
        if patch_embeds is not None:
            if not cfg.vision_tokens:
                raise ValueError(
                    "patch_embeds passed to a config without vision_tokens"
                )
            if self.session.mesh is not None:
                raise ValueError("mesh serving has no vision-prefix path")
            patch_embeds = np.asarray(patch_embeds)
            if patch_embeds.ndim == 3:
                patch_embeds = patch_embeds[0]
            if patch_embeds.shape[0] != cfg.vision_tokens:
                raise ValueError(
                    f"expected {cfg.vision_tokens} vision tokens, got "
                    f"{patch_embeds.shape[0]}"
                )
        prefix = 0 if patch_embeds is None else patch_embeds.shape[0]
        if prefix + prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt ({prefix + prompt.size}) + max_new ({max_new}) "
                f"exceeds engine max_len ({self.max_len})"
            )
        req = Request(
            rid=self._next_rid, prompt=prompt, max_new=int(max_new),
            temperature=float(temperature), key=key, eos_id=eos_id,
            enc_embeds=enc_embeds, patch_embeds=patch_embeds,
            submitted_at=time.perf_counter(),
        )
        self._next_rid += 1
        self.pending.append(req)
        self._admit_pending()
        return req

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if self.slot_req[i] is None]

    def _admit_pending(self) -> None:
        """Assign free slots to queued requests and run each new slot's
        first admission unit (a retired-at-first-token request frees its
        slot for the next queued request immediately)."""
        while self.pending:
            free = self._free_slots()
            if not free:
                return
            slot = free[0]
            req = self.pending.popleft()
            self._start_admission(req, slot)
            self._advance_admission(slot)

    def _bucket(self, n: int) -> int:
        """Pow-2 chunk bucket (masked tail) in
        [min_bucket, prefill_chunk] — the bounded set of chunk widths the
        jitted chunk step ever sees."""
        b = self.min_bucket
        while b < n:
            b *= 2
        return b

    def _spans(self, start: int, n: int) -> List[Tuple[int, int]]:
        return [
            (a, min(a + self.prefill_chunk, n))
            for a in range(start, n, self.prefill_chunk)
        ]

    def _start_admission(self, req: Request, slot: int) -> None:
        """Bind ``req`` to ``slot`` and stage its admission plan: prefix
        cache lookup, then encoder / vision / chunk units as needed."""
        from repro.models import transformer as T

        req.slot = slot
        self.slot_req[slot] = req
        cfg = self.cfg
        n = req.prompt_len
        req._chain = self._hash_chain(req)
        hit = self._prefix_lookup(req)
        if not self.chunked:
            # SSM/RG-LRU: fused exact-length prefill (recurrences do not
            # chunk bitwise); the prefix cache only serves full hits.
            if hit == n:
                return
            with self.session.scope():
                req._logits, req._cache = serving.prefill_and_cache(
                    self.session.params, jnp.asarray(req.prompt)[None, :],
                    cfg, self.max_len, mesh=self.session.mesh,
                )
            self._store_prefix(req, n)
            return
        if hit == n:
            return  # full snapshot hit: cache + logits already staged
        if req._cache is None:  # no partial hit to resume from
            with self.session.scope():
                req._cache = T.init_cache(
                    cfg, 1, self.max_len, src_len=self.src_len
                )
                if cfg.encoder_layers:
                    req._cache = serving.encode_fn(cfg, self.session.mesh)(
                        self.session.params, req._cache,
                        jnp.asarray(req.enc_embeds)[None],
                    )
            req._vision_pending = req.patch_embeds is not None
        req._spans = self._spans(hit, n)

    def _advance_admission(self, slot: int) -> None:
        """Run ONE admission unit (vision prefix or one prompt chunk) for
        the slot; finalize (sample the first token) when the plan is
        exhausted."""
        req = self.slot_req[slot]
        if req is None or self.active[slot] or req.done:
            return
        cfg = self.cfg
        if req._vision_pending:
            with self.session.scope():
                req._cache = serving.prefill_vision_fn(cfg, self.session.mesh)(
                    self.session.params,
                    jnp.asarray(req.patch_embeds)[None], req._cache,
                    self.max_len,
                )
            req._vision_pending = False
            self.prefill_chunks += 1
            if req._spans:
                return  # text chunks continue on the next tick
        elif req._spans:
            a, b_ = req._spans.pop(0)
            with self.session.scope():
                req._logits, req._cache = self._chunk_call(
                    req._cache, req.prompt, a, b_, req.vision_len
                )
            self.prefill_chunks += 1
            self._store_prefix(req, b_)
            if req._spans:
                return
        self._finalize_admission(slot, req)

    def _chunk_call(self, cache, prompt, a, b_, vision_len):
        """One bucketed chunk step: tokens [a, b_) at absolute positions
        ``vision_len + [a, b_)``, zero-padded to the pow-2 bucket."""
        n = b_ - a
        bucket = self._bucket(n)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = prompt[a:b_]
        fn = serving.prefill_chunk_fn(
            self.cfg, self.session.mesh, self.session.params
        )
        return fn(
            self.session.params, jnp.asarray(toks), cache,
            jnp.asarray([vision_len + a], jnp.int32),
            jnp.asarray([n], jnp.int32), self.max_len, 0,
        )

    def _finalize_admission(self, slot: int, req: Request) -> None:
        """Sample the first token from the admission logits and either
        activate the slot for decode ticks or retire immediately (first
        token is EOS / max_new == 1) — same accounting either way."""
        from repro.models import transformer as T

        tok, req.key = serving._next_token(
            req._logits, req.temperature, req.key
        )
        first = int(np.asarray(tok)[0, 0])
        req.ttft_seconds = time.perf_counter() - req.submitted_at
        req.tokens.append(first)
        req.admitted_tick = self.tick
        self.first_tokens += 1
        one = req._cache
        req._cache = None
        req._logits = None
        req._chain = None
        if req.max_new <= 1 or (req.eos_id is not None and first == req.eos_id):
            self._finish(req, slot)  # nothing to decode — recycle the slot
            return
        with self.session.scope():
            self.cache = T.write_cache_slot(self.cache, one, slot)
        self.active[slot] = True
        self.pos[slot] = req.vision_len + req.prompt_len  # next write position
        self.last_tok[slot, 0] = first

    # -- prefix cache --------------------------------------------------------

    def _hash_chain(self, req: Request) -> List[bytes]:
        """chain[k] identifies the request's first k prompt tokens (plus
        the full encoder/vision inputs, which are part of position 0's
        context) — the snapshot key for a cache state with exactly k
        prompt tokens admitted."""
        h = hashlib.sha1(b"rimc-prefix-v1")
        if req.enc_embeds is not None:
            h.update(np.ascontiguousarray(req.enc_embeds).tobytes())
        if req.patch_embeds is not None:
            h.update(np.ascontiguousarray(req.patch_embeds).tobytes())
        chain = [h.digest()]
        for t in req.prompt:
            h2 = hashlib.sha1(chain[-1])
            h2.update(int(t).to_bytes(8, "little", signed=True))
            chain.append(h2.digest())
        return chain

    def _prefix_lookup(self, req: Request) -> int:
        """Longest stored snapshot matching this request's prefix. On a
        hit, stage the snapshot's cache + boundary logits on the request
        and return the number of prompt tokens covered (0 = cold)."""
        if self.prefix_cache_entries <= 0:
            return 0
        self.prefix_lookups += 1
        n = req.prompt_len
        candidates = range(n, 0, -1) if self.chunked else (n,)
        for k in candidates:
            entry = self._prefix_cache.get(req._chain[k])
            if entry is None:
                continue
            toks, cache, logits = entry
            if toks.shape[0] != k or not np.array_equal(toks, req.prompt[:k]):
                continue  # hash collision — treat as miss
            self._prefix_cache.move_to_end(req._chain[k])
            req._cache = cache
            req._logits = logits
            req.prefix_hit_tokens = k
            if k == n:
                self.prefix_hits += 1
            else:
                self.prefix_partial_hits += 1
            return k
        return 0

    def _store_prefix(self, req: Request, k: int) -> None:
        """Snapshot the admission state after k prompt tokens. The jax
        arrays are immutable, so the snapshot stays valid while later
        chunks build new trees on top of it."""
        if self.prefix_cache_entries <= 0:
            return
        key = req._chain[k]
        if key in self._prefix_cache:
            self._prefix_cache.move_to_end(key)
            return
        self._prefix_cache[key] = (
            req.prompt[:k].copy(), req._cache, req._logits
        )
        while len(self._prefix_cache) > self.prefix_cache_entries:
            self._prefix_cache.popitem(last=False)

    # -- decode tick ---------------------------------------------------------

    def step(self) -> bool:
        """Admit what fits, advance every admitting slot by one prefill
        unit, then advance every active slot by one token with a single
        batched ``decode_step``. Returns False when there is nothing left
        to do (no active or admitting slots, empty queue)."""
        self._admit_pending()
        for slot in range(self.max_slots):
            req = self.slot_req[slot]
            if req is not None and not self.active[slot] and not req.done:
                self._advance_admission(slot)
        if not self.active.any():
            busy = bool(self.pending) or any(
                r is not None and not r.done for r in self.slot_req
            )
            if busy:
                self.tick += 1  # an admission-only tick still advances time
            return busy
        t0 = time.perf_counter()
        with self.session.scope():
            # fetch INSIDE the scope: the registry key includes the
            # active backend name, and codes vs codes_adc sessions share
            # identical param avals — a scope-blind fetch would let one
            # hit the other's trace
            step = self.session.decode_step()
            logits, self.cache = step(
                self.session.params, self.cache,
                jnp.asarray(self.last_tok), jnp.asarray(self.pos),
            )
        n_live = int(self.active.sum())
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            tok, req.key = serving._next_token(
                logits[slot : slot + 1], req.temperature, req.key
            )
            t = int(np.asarray(tok)[0, 0])
            req.tokens.append(t)
            self.pos[slot] += 1
            self.last_tok[slot, 0] = t
            hit_eos = req.eos_id is not None and t == req.eos_id
            out_of_room = int(self.pos[slot]) + 1 >= self.max_len
            if len(req.tokens) >= req.max_new or hit_eos or out_of_room:
                self._finish(req, slot)
        self.decode_seconds += time.perf_counter() - t0
        self.decode_tokens += n_live
        self.tick += 1
        return True

    def _finish(self, req: Request, slot: Optional[int] = None) -> None:
        """The single retirement path — first-token EOS, max_new, EOS
        mid-stream, and out-of-room all come through here, so the
        counters stay consistent across every exit."""
        req.done = True
        req.slot = None
        self.completed += 1
        if slot is not None:
            self.slot_req[slot] = None
            self.active[slot] = False

    def run(self) -> None:
        """Drain: admit + step until every submitted request retired."""
        while self.step():
            pass

    # -- elastic degradation -------------------------------------------------

    def remesh(self, new_mesh=None, *, n_failed_hosts: int = 1):
        """A host dropped mid-serve: re-bind the session to the degraded
        mesh and rebuild the decode cache by replaying every in-flight
        slot from its deterministic lifecycle — the prompt plus the
        already-emitted token stream. Returns the ``ElasticPlan``.

        Without an explicit ``new_mesh``, the plan derives it from the
        session's current mesh by dropping ``n_failed_hosts`` data-axis
        rows (``launch.mesh.make_elastic_mesh``); the model axis is
        untouched, so the wrap policy reshards params identically and
        replayed decode is bitwise the undisturbed engine's.

        Replay is per-slot batch-1 through the SAME admission machinery
        the slot originally ran (chunked prefill for attention stacks,
        fused prefill otherwise — the two families are not
        bitwise-interchangeable), then each recorded token re-fed through
        single decode steps at its original position. Host scheduler
        state — per-slot clocks, last sampled token, the request's
        advanced PRNG key — carries over untouched; nothing is
        resampled."""
        from repro.launch.mesh import make_elastic_mesh
        from repro.models import transformer as T
        from repro.runtime.fault import ElasticPlan

        mesh = self.session.mesh
        if new_mesh is None:
            if mesh is None:
                raise ValueError(
                    "remesh needs either an explicit new_mesh or a session "
                    "already bound to a mesh to degrade"
                )
            plan = ElasticPlan.plan(
                n_failed_hosts, self.tick,
                rows=int(mesh.shape["data"]), cols=int(mesh.shape["model"]),
            )
            new_mesh = make_elastic_mesh(n_failed_hosts, base_mesh=mesh)
        else:
            dropped = 0
            if mesh is not None and "data" in mesh.shape:
                dropped = int(mesh.shape["data"]) - int(
                    new_mesh.shape.get("data", 1)
                )
            plan = ElasticPlan(
                failed_hosts=max(dropped, 0),
                new_mesh_shape=tuple(new_mesh.devices.shape),
                restore_step=self.tick,
                notes="explicit re-mesh",
            )
        self.session.reshard(new_mesh)
        with self.session.scope():
            self.cache = T.init_cache(
                self.cfg, self.max_slots, self.max_len, src_len=self.src_len
            )
            step = self.session.decode_step()
            for slot in np.flatnonzero(self.active):
                req = self.slot_req[slot]
                one = self._replay_admission(req)
                # re-feed all but the pending last token: token j was
                # consumed at position vision_len + prompt_len + j; the
                # engine's last_tok/pos still point at the un-issued write
                pos0 = req.vision_len + req.prompt_len
                for j, t in enumerate(req.tokens[:-1]):
                    _, one = step(
                        self.session.params, one,
                        jnp.asarray([[t]], jnp.int32),
                        jnp.asarray([pos0 + j], jnp.int32),
                    )
                self.cache = T.write_cache_slot(self.cache, one, slot)
        return plan

    def _replay_admission(self, req: Request):
        """Rebuild a slot's post-admission batch-1 cache, bitwise equal
        to what admission originally produced (deterministic; prefix-
        cache hits change nothing because a snapshot IS the cold state)."""
        from repro.models import transformer as T

        cfg = self.cfg
        if not self.chunked:
            _, one = serving.prefill_and_cache(
                self.session.params, jnp.asarray(req.prompt)[None, :],
                cfg, self.max_len, mesh=self.session.mesh,
            )
            return one
        one = T.init_cache(cfg, 1, self.max_len, src_len=self.src_len)
        if cfg.encoder_layers:
            one = serving.encode_fn(cfg, self.session.mesh)(
                self.session.params, one, jnp.asarray(req.enc_embeds)[None]
            )
        if req.patch_embeds is not None:
            one = serving.prefill_vision_fn(cfg, self.session.mesh)(
                self.session.params, jnp.asarray(req.patch_embeds)[None],
                one, self.max_len,
            )
        for a, b_ in self._spans(0, req.prompt_len):
            _, one = self._chunk_call(one, req.prompt, a, b_, req.vision_len)
        return one

    # -- introspection -------------------------------------------------------

    @property
    def num_active(self) -> int:
        return int(self.active.sum())

    def compile_count(self) -> int:
        """Compiled-computation count for this engine's (cfg, backend)
        step functions — flat across requests once warm (the retrace
        regression metric)."""
        with self.session.scope():
            return serving.compile_count(self.cfg, self.session.mesh)

    def stats(self) -> dict:
        return {
            "ticks": self.tick,
            "decode_seconds": self.decode_seconds,
            "decode_tokens": self.decode_tokens,
            "first_tokens": self.first_tokens,
            "generated_tokens": self.generated_tokens,
            "completed": self.completed,
            "prefill_chunks": self.prefill_chunks,
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_partial_hits": self.prefix_partial_hits,
            "decode_tok_per_s": (
                self.decode_tokens / self.decode_seconds
                if self.decode_seconds > 0 else float("nan")
            ),
            "compile_count": self.compile_count(),
        }


def _pow2_ceil(n: int) -> int:
    if n < 1:
        raise ValueError(f"need a positive size, got {n}")
    b = 1
    while b < n:
        b *= 2
    return b
