"""Continuous-batching serve engine over the vectorized decode step.

The paper's deployment story ends here: the RRAM base is frozen, the
DoRA side-cars are merged into the kernel epilogue, and every decoded
token pays one crossbar matmul plus the low-rank epilogue. What this
module adds is the traffic shape of that story — many concurrent
requests with ragged prompts, arriving and finishing at different times,
all advanced by ONE compiled batched ``decode_step``:

* **Slots.** A fixed ``(max_slots, max_len)`` decode cache is allocated
  once. Each in-flight request owns one slot (one batch row); finished
  slots are recycled for queued requests.
* **Per-slot clocks.** ``pos`` is a ``(B,)`` int32 vector — every slot
  sits at its own sequence offset, so ragged prompt lengths and
  mid-stream admission need no padding or lockstep restarts.
* **Admission = prefill into a slot.** ``submit()`` runs the fused
  full-sequence prefill for the new request (batch=1, the engine's
  ``max_len``) and scatters the resulting K/V / latents / recurrent
  state into the slot's row (``transformer.write_cache_slot``). The
  first token is sampled from the prefill logits (time-to-first-token is
  recorded per request).
* **One jitted step for everyone.** ``step()`` advances ALL active slots
  with a single ``decode_step_fn(cfg)`` call — compiled once per
  ``(cfg, backend)`` in ``deploy.serving`` and reused across requests,
  sessions, and engines (the retrace fix). Inactive slots ride along as
  dead rows: their writes land in recycled cache lines that the per-slot
  validity masks keep invisible to live requests.
* **Per-slot stopping.** A request retires when it samples its
  ``eos_id`` or hits ``max_new`` / ``max_len``; its slot frees
  immediately and the admission loop refills it on the next tick.

Determinism: every row of the batched step computes exactly what a
single-request ``serving.generate`` call computes (row-independent
kernels + per-slot masks), so engine output is bitwise-identical to N
independent ``generate`` calls — tests/test_engine.py pins this on the
``dequant`` and ``codes`` backends, ragged + staggered.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.deploy import serving


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle record."""

    rid: int
    prompt: np.ndarray               # (s,) int32
    max_new: int
    temperature: float = 0.0
    key: Optional[jax.Array] = None  # advanced as the request samples
    eos_id: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: Optional[int] = None       # None while queued / after retiring
    admitted_tick: Optional[int] = None
    submitted_at: Optional[float] = None  # perf_counter at submit()
    ttft_seconds: Optional[float] = None  # submit -> first token (incl. queue wait)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


class ServeEngine:
    """Slot-based continuous-batching scheduler over a ``ServeSession``.

    ``submit()`` admits (or queues) a request; ``step()`` advances every
    active slot by one token; ``run()`` drains the queue. Decoder-only
    configs (the engine recomputes nothing per slot except the token
    stream; cross-attention serving stays on ``serving.generate``).
    """

    def __init__(self, session, *, max_slots: int = 4, max_len: int = 128):
        from repro.models import transformer as T

        if session.cfg.encoder_layers:
            raise NotImplementedError(
                "ServeEngine is decoder-only; encoder-decoder serving "
                "goes through serving.generate"
            )
        self.session = session
        self.cfg = session.cfg
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        with session.scope():
            self.cache = T.init_cache(self.cfg, self.max_slots, self.max_len)
        # per-slot clocks / occupancy (host-side scheduler state)
        self.pos = np.zeros(self.max_slots, np.int32)
        self.active = np.zeros(self.max_slots, bool)
        self.last_tok = np.zeros((self.max_slots, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * self.max_slots
        self.pending: Deque[Request] = deque()
        self.tick = 0
        self.decode_seconds = 0.0   # time inside batched decode steps
        self.decode_tokens = 0      # tokens produced by those steps
        self._next_rid = 0

    # -- admission -----------------------------------------------------------

    def submit(
        self, prompt, *, max_new: int = 16, temperature: float = 0.0,
        key: Optional[jax.Array] = None, eos_id: Optional[int] = None,
    ) -> Request:
        """Enqueue a request; admits it immediately if a slot is free.
        ``prompt`` is a (s,) or (1, s) int token array."""
        serving._check_sampling_args(temperature, key)
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new ({max_new}) exceeds "
                f"engine max_len ({self.max_len})"
            )
        req = Request(
            rid=self._next_rid, prompt=prompt, max_new=int(max_new),
            temperature=float(temperature), key=key, eos_id=eos_id,
            submitted_at=time.perf_counter(),
        )
        self._next_rid += 1
        self.pending.append(req)
        self._admit_pending()
        return req

    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if self.slot_req[i] is None]

    def _admit_pending(self) -> None:
        from repro.models import transformer as T

        free = self._free_slots()
        while free and self.pending:
            slot = free.pop(0)
            req = self.pending.popleft()
            with self.session.scope():
                logits, one = serving.prefill_and_cache(
                    self.session.params, jnp.asarray(req.prompt)[None, :],
                    self.cfg, self.max_len, mesh=self.session.mesh,
                )
                self.cache = T.write_cache_slot(self.cache, one, slot)
            tok, req.key = serving._next_token(logits, req.temperature, req.key)
            first = int(np.asarray(tok)[0, 0])
            req.ttft_seconds = time.perf_counter() - req.submitted_at
            req.tokens.append(first)
            req.admitted_tick = self.tick
            if req.max_new <= 1 or first == req.eos_id:
                req.done = True  # nothing to decode — hand the slot back
                free.insert(0, slot)
                continue
            req.slot = slot
            self.slot_req[slot] = req
            self.active[slot] = True
            self.pos[slot] = req.prompt_len  # next write position
            self.last_tok[slot, 0] = first

    # -- decode tick ---------------------------------------------------------

    def step(self) -> bool:
        """Admit what fits, then advance every active slot by one token
        with a single batched ``decode_step``. Returns False when there
        is nothing left to do (no active slots, empty queue)."""
        self._admit_pending()
        if not self.active.any():
            return bool(self.pending)
        t0 = time.perf_counter()
        with self.session.scope():
            # fetch INSIDE the scope: the registry key includes the
            # active backend name, and codes vs codes_adc sessions share
            # identical param avals — a scope-blind fetch would let one
            # hit the other's trace
            step = self.session.decode_step()
            logits, self.cache = step(
                self.session.params, self.cache,
                jnp.asarray(self.last_tok), jnp.asarray(self.pos),
            )
        n_live = int(self.active.sum())
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            tok, req.key = serving._next_token(
                logits[slot : slot + 1], req.temperature, req.key
            )
            t = int(np.asarray(tok)[0, 0])
            req.tokens.append(t)
            self.pos[slot] += 1
            self.last_tok[slot, 0] = t
            hit_eos = req.eos_id is not None and t == req.eos_id
            out_of_room = int(self.pos[slot]) + 1 >= self.max_len
            if len(req.tokens) >= req.max_new or hit_eos or out_of_room:
                self._retire(slot)
        self.decode_seconds += time.perf_counter() - t0
        self.decode_tokens += n_live
        self.tick += 1
        return True

    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done = True
        req.slot = None
        self.slot_req[slot] = None
        self.active[slot] = False

    def run(self) -> None:
        """Drain: admit + step until every submitted request retired."""
        while self.step():
            pass

    # -- elastic degradation -------------------------------------------------

    def remesh(self, new_mesh=None, *, n_failed_hosts: int = 1):
        """A host dropped mid-serve: re-bind the session to the degraded
        mesh and rebuild the decode cache by replaying every in-flight
        slot from its deterministic lifecycle — the prompt plus the
        already-emitted token stream. Returns the ``ElasticPlan``.

        Without an explicit ``new_mesh``, the plan derives it from the
        session's current mesh by dropping ``n_failed_hosts`` data-axis
        rows (``launch.mesh.make_elastic_mesh``); the model axis is
        untouched, so the wrap policy reshards params identically and
        replayed decode is bitwise the undisturbed engine's.

        Replay is per-slot batch-1: fused prefill over the prompt, then
        each recorded token re-fed through single decode steps at its
        original position (the fused-prefill and per-token paths are not
        bitwise-interchangeable, so the replay must retrace the engine's
        actual decode history). Host scheduler state — per-slot clocks,
        last sampled token, the request's advanced PRNG key — carries
        over untouched; nothing is resampled.
        """
        from repro.launch.mesh import make_elastic_mesh
        from repro.models import transformer as T
        from repro.runtime.fault import ElasticPlan

        mesh = self.session.mesh
        if new_mesh is None:
            if mesh is None:
                raise ValueError(
                    "remesh needs either an explicit new_mesh or a session "
                    "already bound to a mesh to degrade"
                )
            plan = ElasticPlan.plan(
                n_failed_hosts, self.tick,
                rows=int(mesh.shape["data"]), cols=int(mesh.shape["model"]),
            )
            new_mesh = make_elastic_mesh(n_failed_hosts, base_mesh=mesh)
        else:
            dropped = 0
            if mesh is not None and "data" in mesh.shape:
                dropped = int(mesh.shape["data"]) - int(
                    new_mesh.shape.get("data", 1)
                )
            plan = ElasticPlan(
                failed_hosts=max(dropped, 0),
                new_mesh_shape=tuple(new_mesh.devices.shape),
                restore_step=self.tick,
                notes="explicit re-mesh",
            )
        self.session.reshard(new_mesh)
        with self.session.scope():
            self.cache = T.init_cache(self.cfg, self.max_slots, self.max_len)
            step = self.session.decode_step()
            for slot in np.flatnonzero(self.active):
                req = self.slot_req[slot]
                _, one = serving.prefill_and_cache(
                    self.session.params, jnp.asarray(req.prompt)[None, :],
                    self.cfg, self.max_len, mesh=self.session.mesh,
                )
                # re-feed all but the pending last token: token j was
                # consumed at position prompt_len + j; the engine's
                # last_tok/pos still point at the un-issued write
                for j, t in enumerate(req.tokens[:-1]):
                    _, one = step(
                        self.session.params, one,
                        jnp.asarray([[t]], jnp.int32),
                        jnp.asarray([req.prompt_len + j], jnp.int32),
                    )
                self.cache = T.write_cache_slot(self.cache, one, slot)
        return plan

    # -- introspection -------------------------------------------------------

    @property
    def num_active(self) -> int:
        return int(self.active.sum())

    def compile_count(self) -> int:
        """Compiled-computation count for this engine's (cfg, backend)
        step functions — flat across requests once warm (the retrace
        regression metric)."""
        with self.session.scope():
            return serving.compile_count(self.cfg, self.session.mesh)

    def stats(self) -> dict:
        return {
            "ticks": self.tick,
            "decode_seconds": self.decode_seconds,
            "decode_tokens": self.decode_tokens,
            "decode_tok_per_s": (
                self.decode_tokens / self.decode_seconds
                if self.decode_seconds > 0 else float("nan")
            ),
            "compile_count": self.compile_count(),
        }
