"""Device non-ideality suite: composable fault injection on the
crossbar substrate.

Data model (``faults/map.py``): ``LeafFaults`` per RRAM leaf /
``FaultMap`` per model, registered pytrees whose composition is a
commutative, idempotent lattice join. Events (``faults/generators.py``):
serializable ``FaultSpec``s — ``stuck_at``, ``saturated``,
``retention``, ``iv_nonlinearity`` — that materialize into maps with
drift-style ``fold_in(key, crc32(path))`` keying (and a chip fold for
fleets). Injection surfaces as ``Deployment.inject(faults)`` /
``Fleet.inject(faults, chips=...)``; application happens at code
read-back through ``substrate.faulted_codes``, so every backend and the
prepared/fused serve path see identical faulty weights. The
accuracy-recovery experiment lives in ``faults/study.py``.
"""
from repro.faults.generators import (  # noqa: F401
    FAULT_KINDS,
    FaultSpec,
    build_fleet_map,
    build_map,
    iv_nonlinearity,
    retention,
    saturated,
    stuck_at,
)
from repro.faults.map import (  # noqa: F401
    FaultMap,
    LeafFaults,
    apply_fault_map,
    compose_maps,
)
from repro.faults.study import (  # noqa: F401
    FAULT_CLASSES,
    default_spec,
    fault_recovery_study,
)
