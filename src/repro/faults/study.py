"""Fault-recovery study: the paper's "calibrate, don't reprogram" claim
exercised against every fault class.

For each fault class this programs a deployment, ages it in the field,
injects the fault, and then runs DoRA calibration (Algorithm 1 —
SRAM side-cars only, zero RRAM writes) — recording the teacher/student
logit MSE at each lifecycle point:

    clean      — programmed + drifted, before the fault
    faulted    — after injection, before any recovery
    calibrated — after DoRA calibration on the FAULTY base

``recovered_fraction`` is the share of the faulted error calibration
removed. The default parameters run at the paper's calibration scale
(10 samples, 20 epochs); ``benchmarks/faults_bench.py`` drives this
study, gates on ``calibrated < faulted`` for every class, and commits
the result as ``BENCH_faults.json``.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

FAULT_CLASSES = ("stuck_at", "saturated", "retention", "iv_nonlinearity")


def default_spec(kind: str, seed: int = 1):
    """The study's reference severity per fault class: strong enough to
    measurably degrade logits, survivable enough that a rank-8 side-car
    can compensate."""
    from repro.faults import generators as G

    if kind == "stuck_at":
        return G.stuck_at(seed, rate=0.02, lrs_fraction=0.5)
    if kind == "saturated":
        return G.saturated(seed, rate=0.10, cap_fraction=0.6)
    if kind == "retention":
        return G.retention(seed, rate=0.10, retain=0.6)
    if kind == "iv_nonlinearity":
        return G.iv_nonlinearity(1.5)
    raise ValueError(f"unknown fault class {kind!r}; known: {FAULT_CLASSES}")


def fault_recovery_study(
    arch: str = "qwen3_1_7b", *, smoke: bool = True, samples: int = 10,
    steps: int = 20, seq_len: int = 32, hours: float = 300.0, seed: int = 0,
    classes: Optional[Sequence[str]] = None, backend: str = "dequant",
) -> Dict[str, Dict[str, float]]:
    """Run the study; returns per-class metric dicts. Deterministic in
    every argument (the calibration batch, programming, drift, and fault
    draws are all keyed)."""
    from repro.configs import get_arch
    from repro.deploy.deployment import Deployment, calibration_batch

    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.smoke
    batch = calibration_batch(cfg, samples, seq_len)
    results: Dict[str, Dict[str, float]] = {}
    for kind in classes or FAULT_CLASSES:
        dep = Deployment.program(cfg, seed, backend=backend)
        dep.advance(hours)
        clean = dep.logit_mse(batch)
        dep.inject(default_spec(kind, seed + 1))
        faulted = dep.logit_mse(batch)
        report = dep.calibrate(batch, steps=steps)
        calibrated = dep.logit_mse(batch)
        results[kind] = {
            "clean_mse": float(clean),
            "faulted_mse": float(faulted),
            "calibrated_mse": float(calibrated),
            "recovered_fraction": (
                float((faulted - calibrated) / faulted) if faulted > 0 else 0.0
            ),
            "calib_final_feature_mse": float(report.final_loss),
            "calib_epochs": int(report.epochs_run),
            "hours": float(hours),
        }
    return results
