"""Composable per-leaf fault maps over the crossbar substrate.

A ``FaultMap`` describes device non-idealities as data: one
``LeafFaults`` record per RRAM leaf (keyed by the same crc32 path
strings the drift clock uses), each holding the per-cell fault state for
the positive and negative device arrays of the differential pair. The
map is a registered pytree, so a fleet-scale map simply carries a
leading chip axis on every field and rides the same ``jax.vmap``
dispatches as the stacked codes.

Faults apply at code READ-BACK: the resident (pristine) codes are never
mutated. ``apply_fault_map`` derives a faulty uint8 codes view, and
every consumer — the ``codes``/``dequant``/``codes_adc`` backends, the
prepared/fused serve path, the fleet's drift proxy — reads that one
view, which is what makes backend parity under faults bitwise by
construction (``substrate/exec.py::faulted_codes`` is the choke point).

Composition semantics are a lattice, so ``compose`` is commutative and
idempotent by construction (the hypothesis property in
``tests/test_properties.py`` pins this):

* stuck cells — masks OR, pinned codes combine by ``maximum`` (a cell
  stuck at LRS by either map is LRS in the composite);
* saturation caps — elementwise ``minimum`` (the tighter clamp wins);
* retention factors — elementwise ``minimum`` (the worse decay wins);
* I-V non-linearity strength — ``maximum``.

Application order within one leaf is canonical and fixed — retention
decay, then I-V read distortion, then saturation clamp, then stuck
pins — so a composite map has ONE meaning regardless of the order its
parts were injected in. Every stage is elementwise on the code grid,
which is why a chip-stacked map broadcasts through without any special
casing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import rram
from repro.core.calibrate import _path_str

_FIELDS = (
    "stuck_mask_pos", "stuck_val_pos", "stuck_mask_neg", "stuck_val_neg",
    "cap_pos", "cap_neg", "retain_pos", "retain_neg", "iv_strength",
)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LeafFaults:
    """Fault state for one RRAM leaf. ``None`` fields are exact
    identities for their stage (not all-default arrays), so a map built
    by one generator stays as small as what it actually pins.

    Shapes match the leaf's device arrays (``g_pos``/``g_neg``), with an
    optional leading chip axis; ``iv_strength`` is a scalar (or a
    per-chip vector) — I-V bending is a read-path property of the whole
    column driver, not of single cells."""

    stuck_mask_pos: Optional[jax.Array] = None  # bool, True = pinned
    stuck_val_pos: Optional[jax.Array] = None   # uint8, 0 outside masks
    stuck_mask_neg: Optional[jax.Array] = None
    stuck_val_neg: Optional[jax.Array] = None
    cap_pos: Optional[jax.Array] = None         # uint8 clamp, code_max = no-op
    cap_neg: Optional[jax.Array] = None
    retain_pos: Optional[jax.Array] = None      # f32 in [0, 1], 1 = no decay
    retain_neg: Optional[jax.Array] = None
    iv_strength: Optional[jax.Array] = None     # f32 >= 0, 0 = linear read

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    def compose(self, other: "LeafFaults") -> "LeafFaults":
        """Lattice join of two fault records (commutative, idempotent)."""

        def comb(a, b, f):
            if a is None:
                return b
            if b is None:
                return a
            return f(a, b)

        return LeafFaults(
            stuck_mask_pos=comb(
                self.stuck_mask_pos, other.stuck_mask_pos, jnp.logical_or
            ),
            stuck_val_pos=comb(self.stuck_val_pos, other.stuck_val_pos, jnp.maximum),
            stuck_mask_neg=comb(
                self.stuck_mask_neg, other.stuck_mask_neg, jnp.logical_or
            ),
            stuck_val_neg=comb(self.stuck_val_neg, other.stuck_val_neg, jnp.maximum),
            cap_pos=comb(self.cap_pos, other.cap_pos, jnp.minimum),
            cap_neg=comb(self.cap_neg, other.cap_neg, jnp.minimum),
            retain_pos=comb(self.retain_pos, other.retain_pos, jnp.minimum),
            retain_neg=comb(self.retain_neg, other.retain_neg, jnp.minimum),
            iv_strength=comb(self.iv_strength, other.iv_strength, jnp.maximum),
        )

    def _apply_device(self, g, mask, val, cap, retain, code_max: int):
        gf = g.astype(jnp.float32)
        if retain is not None:
            gf = jnp.round(gf * retain.astype(jnp.float32))
        if self.iv_strength is not None:
            s = jnp.asarray(self.iv_strength, jnp.float32)
            s = s.reshape(s.shape + (1,) * (gf.ndim - s.ndim))
            ss = jnp.maximum(s, 1e-6)
            u = gf / float(code_max)
            bent = jnp.round(float(code_max) * jnp.sinh(ss * u) / jnp.sinh(ss))
            gf = jnp.where(s > 0.0, bent, gf)
        if cap is not None:
            gf = jnp.minimum(gf, cap.astype(jnp.float32))
        if mask is not None:
            gf = jnp.where(mask, val.astype(jnp.float32), gf)
        return jnp.clip(jnp.round(gf), 0, code_max).astype(jnp.uint8)

    def apply(self, xw: rram.CrossbarWeight, cfg: rram.RramConfig):
        """The faulty read-back view of one leaf's codes. The input codes
        are never mutated; the per-column scale is untouched (faults live
        in the analog cells, not the digital periphery)."""
        if all(getattr(self, f) is None for f in _FIELDS):
            return xw
        cm = int(cfg.code_max)
        return rram.CrossbarWeight(
            self._apply_device(
                xw.g_pos, self.stuck_mask_pos, self.stuck_val_pos,
                self.cap_pos, self.retain_pos, cm,
            ),
            self._apply_device(
                xw.g_neg, self.stuck_mask_neg, self.stuck_val_neg,
                self.cap_neg, self.retain_neg, cm,
            ),
            xw.scale,
        )


@jax.tree_util.register_pytree_node_class
class FaultMap:
    """Path-string -> ``LeafFaults`` for a whole model (or fleet). A
    registered pytree: stacked fleet maps vmap/slice like the stacked
    codes they describe."""

    def __init__(self, leaves: Dict[str, LeafFaults]):
        self.leaves = dict(leaves)

    def tree_flatten(self):
        keys = tuple(sorted(self.leaves))
        return tuple(self.leaves[k] for k in keys), keys

    @classmethod
    def tree_unflatten(cls, keys, children):
        return cls(dict(zip(keys, children)))

    def compose(self, other: "FaultMap") -> "FaultMap":
        """Merge two maps leaf-by-leaf (``LeafFaults.compose`` on shared
        paths). Commutative and idempotent like the leaf join."""
        merged = dict(self.leaves)
        for path, lf in other.leaves.items():
            merged[path] = merged[path].compose(lf) if path in merged else lf
        return FaultMap(merged)

    __or__ = compose

    def __len__(self) -> int:
        return len(self.leaves)

    def __repr__(self) -> str:
        return f"FaultMap({len(self.leaves)} leaves)"


def compose_maps(maps) -> Optional[FaultMap]:
    """Fold a sequence of maps into one composite (None for empty)."""
    out: Optional[FaultMap] = None
    for m in maps:
        if m is None:
            continue
        out = m if out is None else out.compose(m)
    return out


def apply_fault_map(tree, fmap: Optional[FaultMap], cfg: rram.RramConfig):
    """Derive the faulty codes view of ``tree``: every ``CrossbarWeight``
    leaf with an entry in ``fmap`` is read back through its fault record;
    everything else passes through as the same buffers. ``None`` is the
    healthy identity."""
    if fmap is None:
        return tree

    def leaf(path, x):
        if not isinstance(x, rram.CrossbarWeight):
            return x
        lf = fmap.leaves.get(_path_str(path))
        if lf is None:
            return x
        return lf.apply(x, cfg)

    return jax.tree_util.tree_map_with_path(
        leaf, tree, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
