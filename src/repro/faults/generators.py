"""Fault generators: serializable ``FaultSpec`` events that materialize
into ``FaultMap``s.

A spec is the *event*, the map is the *state* — exactly the split the
drift clock uses (``drift_hours`` replays into codes). Specs are plain
frozen records (kind + parameters + raw PRNG key words), so
``Deployment.snapshot``/``Fleet.snapshot`` store them as JSON and
restore replays them bitwise. Per-leaf draws key off
``fold_in(spec_key, crc32(path))`` — the drift-event keying — and the
fleet folds the chip index in first (``spec.for_chip(i)``), which is
what makes ``Fleet.inject`` on N chips bitwise identical to N
independent ``Deployment.inject`` runs.

The four fault classes (taxonomy table in README "Non-ideality suite"):

* ``stuck_at``        — cells pinned to LRS (``code_max``) or HRS (0);
                        forming/endurance failures (8-bit RIMC core,
                        arxiv 2008.11669).
* ``saturated``       — cells clamped below ``code_max``; compliance-
                        limited programming (arxiv 2008.11669).
* ``retention``       — deterministic multiplicative code decay on a
                        random cell subset (ReRAM-aware finetuning,
                        arxiv 2606.17471).
* ``iv_nonlinearity`` — read-path distortion of the effective
                        conductance, ``sinh``-bent like the device I-V
                        curve (arxiv 2606.17471). Keyless: it is a
                        column-driver property, not a per-cell draw.

ADC clipping intentionally stays in the ``codes_adc`` backend — it is a
periphery property of a READ, not array state — but its limits come
from the same ``RramConfig`` (``deploy/serving.py::backend_scope``
raises on a conflicting override).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rram
from repro.core.calibrate import _path_str
from repro.faults.map import FaultMap, LeafFaults

FAULT_KINDS = ("stuck_at", "saturated", "retention", "iv_nonlinearity")


def _key_words(key) -> Tuple[int, ...]:
    """Normalize an int seed / PRNGKey to raw uint32 words (JSON-safe)."""
    if isinstance(key, (int, np.integer)):
        key = jax.random.PRNGKey(int(key))
    return tuple(int(v) for v in np.asarray(key).reshape(-1))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injectable fault event: kind + parameters + PRNG key words
    (``None`` for keyless kinds). Hashable, JSON-serializable, and
    replayable — snapshot/restore round-trips these verbatim."""

    kind: str
    params: Tuple[Tuple[str, float], ...]
    key_data: Optional[Tuple[int, ...]] = None

    @property
    def param(self) -> Dict[str, float]:
        return dict(self.params)

    def key(self) -> jax.Array:
        return jnp.asarray(self.key_data, jnp.uint32)

    def for_chip(self, chip: int) -> "FaultSpec":
        """The per-chip event: chip index folded into the spec key, so a
        solo ``Deployment.inject(spec.for_chip(i))`` draws bitwise what
        ``Fleet.inject(spec, chips=[i])`` drew for chip ``i``."""
        if self.key_data is None:
            return self
        folded = jax.random.fold_in(self.key(), int(chip))
        return dataclasses.replace(self, key_data=_key_words(folded))

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "params": dict(self.params),
            "key_data": None if self.key_data is None else list(self.key_data),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        kd = d.get("key_data")
        return cls(
            kind=d["kind"],
            params=tuple(sorted((k, float(v)) for k, v in d["params"].items())),
            key_data=None if kd is None else tuple(int(v) for v in kd),
        )


def _spec(kind: str, key, **params) -> FaultSpec:
    return FaultSpec(
        kind=kind,
        params=tuple(sorted((k, float(v)) for k, v in params.items())),
        key_data=None if key is None else _key_words(key),
    )


def _check_rate(rate: float) -> float:
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"fault rate must be in [0, 1], got {rate}")
    return float(rate)


def stuck_at(key, *, rate: float, lrs_fraction: float = 0.5) -> FaultSpec:
    """Cells pinned to a rail: each device cell sticks with probability
    ``rate``; of those, ``lrs_fraction`` pin to LRS (``code_max``), the
    rest to HRS (0). Drift can no longer move them — the faulty view
    re-pins after every ``advance``."""
    if not 0.0 <= lrs_fraction <= 1.0:
        raise ValueError(f"lrs_fraction must be in [0, 1], got {lrs_fraction}")
    return _spec("stuck_at", key, rate=_check_rate(rate),
                 lrs_fraction=lrs_fraction)


def saturated(key, *, rate: float, cap_fraction: float = 0.75) -> FaultSpec:
    """Cells that cannot reach full conductance: with probability
    ``rate`` a cell's readable code clamps at
    ``round(cap_fraction * code_max)``."""
    if not 0.0 < cap_fraction <= 1.0:
        raise ValueError(f"cap_fraction must be in (0, 1], got {cap_fraction}")
    return _spec("saturated", key, rate=_check_rate(rate),
                 cap_fraction=cap_fraction)


def retention(key, *, rate: float, retain: float = 0.5) -> FaultSpec:
    """Retention loss: with probability ``rate`` a cell's code decays to
    ``round(code * retain)`` — deterministic and replayable, keyed like
    a drift event (not a drift draw: retention is a persistent floor,
    drift is a diffusion)."""
    if not 0.0 <= retain <= 1.0:
        raise ValueError(f"retain must be in [0, 1], got {retain}")
    return _spec("retention", key, rate=_check_rate(rate), retain=retain)


def iv_nonlinearity(strength: float) -> FaultSpec:
    """Read-path I-V distortion: the effective conductance at read is
    ``code_max * sinh(s*u)/sinh(s)`` for normalized code ``u`` —
    ``s=0`` is the linear (healthy) read. Applies to every RRAM leaf;
    keyless."""
    if strength < 0:
        raise ValueError(f"strength must be >= 0, got {strength}")
    return _spec("iv_nonlinearity", None, strength=strength)


# ---------------------------------------------------------------------------
# materialization
# ---------------------------------------------------------------------------


def _leaf_fault(
    spec: FaultSpec, key: Optional[jax.Array], shape, cfg: rram.RramConfig,
) -> LeafFaults:
    """Draw one leaf's fault record (pure in (spec, key, shape) — the
    fleet vmaps this over per-chip keys)."""
    cm = int(cfg.code_max)
    p = spec.param
    if spec.kind == "iv_nonlinearity":
        return LeafFaults(iv_strength=jnp.float32(p["strength"]))
    kp, kn = jax.random.split(key)
    up = jax.random.uniform(kp, shape)
    un = jax.random.uniform(kn, shape)
    rate = p["rate"]
    if spec.kind == "stuck_at":
        lrs = rate * p["lrs_fraction"]
        return LeafFaults(
            stuck_mask_pos=up < rate,
            stuck_val_pos=jnp.where(up < lrs, cm, 0).astype(jnp.uint8),
            stuck_mask_neg=un < rate,
            stuck_val_neg=jnp.where(un < lrs, cm, 0).astype(jnp.uint8),
        )
    if spec.kind == "saturated":
        cap = round(p["cap_fraction"] * cm)
        return LeafFaults(
            cap_pos=jnp.where(up < rate, cap, cm).astype(jnp.uint8),
            cap_neg=jnp.where(un < rate, cap, cm).astype(jnp.uint8),
        )
    if spec.kind == "retention":
        r = p["retain"]
        return LeafFaults(
            retain_pos=jnp.where(up < rate, r, 1.0).astype(jnp.float32),
            retain_neg=jnp.where(un < rate, r, 1.0).astype(jnp.float32),
        )
    raise ValueError(f"unknown fault kind {spec.kind!r}; known: {FAULT_KINDS}")


def _rram_leaves(tree) -> List[Tuple[str, rram.CrossbarWeight]]:
    out: List[Tuple[str, rram.CrossbarWeight]] = []

    def visit(path, x):
        if isinstance(x, rram.CrossbarWeight):
            out.append((_path_str(path), x))
        return x

    jax.tree_util.tree_map_with_path(
        visit, tree, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
    return out


def _path_key(spec: FaultSpec, path: str) -> Optional[jax.Array]:
    if spec.key_data is None:
        return None
    h = jnp.uint32(zlib.crc32(path.encode()))
    return jax.random.fold_in(spec.key(), h)


def build_map(codes, spec: FaultSpec, cfg: rram.RramConfig) -> FaultMap:
    """Materialize a spec over one deployment's codes tree: one
    ``LeafFaults`` per RRAM leaf, drawn from
    ``fold_in(spec_key, crc32(path))``."""
    leaves = {
        path: _leaf_fault(spec, _path_key(spec, path), xw.g_pos.shape, cfg)
        for path, xw in _rram_leaves(codes)
    }
    return FaultMap(leaves)


def build_fleet_map(
    per_chip_codes, spec: FaultSpec, cfg: rram.RramConfig,
    chips: Sequence[int], n_chips: int,
) -> FaultMap:
    """Materialize a spec over a fleet: per-chip draws (vmapped over
    ``fold_in(spec_key, chip)``) for the selected ``chips``, expanded to
    the full chip axis with exact-identity rows elsewhere. Chip ``i``'s
    row is bitwise ``build_map(codes_i, spec.for_chip(i))``.

    ``per_chip_codes`` supplies the PER-CHIP leaf shapes (e.g.
    ``fleet.chip(0).codes``); the returned map's fields carry a leading
    ``(n_chips, ...)`` axis matching the stacked codes."""
    chips = [int(c) for c in chips]
    idx = jnp.asarray(chips, jnp.int32)
    cm = int(cfg.code_max)
    leaves: Dict[str, LeafFaults] = {}
    for path, xw in _rram_leaves(per_chip_codes):
        shape = xw.g_pos.shape
        if spec.key_data is None:
            # keyless (iv): per-chip strength vector, zero = healthy row
            strength = float(spec.param["strength"])
            full = jnp.zeros((n_chips,), jnp.float32).at[idx].set(strength)
            leaves[path] = LeafFaults(iv_strength=full)
            continue
        h = jnp.uint32(zlib.crc32(path.encode()))
        sub = jax.vmap(
            lambda c: _leaf_fault(
                spec,
                jax.random.fold_in(jax.random.fold_in(spec.key(), c), h),
                shape, cfg,
            )
        )(jnp.asarray(chips, jnp.uint32))

        def expand(field, fill, dtype):
            if field is None:
                return None
            full = jnp.full((n_chips,) + shape, fill, dtype)
            return full.at[idx].set(field)

        leaves[path] = LeafFaults(
            stuck_mask_pos=expand(sub.stuck_mask_pos, False, jnp.bool_),
            stuck_val_pos=expand(sub.stuck_val_pos, 0, jnp.uint8),
            stuck_mask_neg=expand(sub.stuck_mask_neg, False, jnp.bool_),
            stuck_val_neg=expand(sub.stuck_val_neg, 0, jnp.uint8),
            cap_pos=expand(sub.cap_pos, cm, jnp.uint8),
            cap_neg=expand(sub.cap_neg, cm, jnp.uint8),
            retain_pos=expand(sub.retain_pos, 1.0, jnp.float32),
            retain_neg=expand(sub.retain_neg, 1.0, jnp.float32),
        )
    return FaultMap(leaves)
