"""Logical sharding rules: parameter/cache/batch path patterns ->
PartitionSpec, resolved against a concrete mesh.

Axes convention (launch/mesh.py):
  dp axes — ("data",) single-pod, ("pod", "data") multi-pod: batch dim.
  tp axis — "model": attention heads / MLP hidden / expert ff / vocab.

Rules are written for the *trailing* dims of each leaf; leading stacked
dims (scan groups, expert stacks already covered explicitly) are padded
with None. A spec axis is dropped (-> None) when the dim size is not
divisible by the mesh axis size — e.g. batch=1 long_500k cells replicate
the batch dim instead of failing to lower.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# (regex on leaf path, spec for trailing dims). First match wins.
# "D" -> dp axes, "T" -> tp axis, None -> replicated dim.
PARAM_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"embed/embedding$", ("T", None)),
    (r"lm_head/w$", (None, "T")),
    # attention projections
    (r"mixer/(q|k|v|k_up|v_up)/w$", (None, "T")),
    (r"mixer/kv_down/w$", (None, None)),  # tiny MLA latent projection
    (r"mixer/o/w$", ("T", None)),
    # serve-time fused leaves (substrate/prepared.py concatenates
    # same-input siblings over N): columns stay column-parallel. The
    # _q_kvd fusion drags the tiny kv_down columns along — harmless,
    # column independence makes any contiguous partition exact.
    (r"mixer/(_qkv|_q_kvd|_kup_vup)/w$", (None, "T")),
    (r"ffn(/shared)?/_gate_up/w$", (None, "T")),
    (r"xattn/(q|k|v)/w$", (None, "T")),
    (r"xattn/o/w$", ("T", None)),
    # dense MLP
    (r"ffn/(gate|up)/w$", (None, "T")),
    (r"ffn/down/w$", ("T", None)),
    # MoE expert stacks (E, d, f) / (E, f, d): expert-parallel over the
    # model axis when E divides it (deepseek-v2: 64 experts -> 4/device;
    # the combine is a (tokens, d) psum, §Perf H-2); otherwise fall back
    # to 2D (d over data, ff over model) — mixtral-8x22b's 8x22B experts
    # exceed one HBM at 16-way TP (§Perf H-0).
    (r"ffn/(gate_w|up_w)$", ("EP", "D", "T")),
    (r"ffn/down_w$", ("EP", "T", "D")),
    (r"ffn/router/w$", (None, None)),
    (r"ffn/shared/(gate|up)/w$", (None, "T")),
    (r"ffn/shared/down/w$", ("T", None)),
    # Mamba SSM
    (r"mixer/in_proj/w$", (None, "T")),
    (r"mixer/x_proj/w$", ("T", None)),
    (r"mixer/dt_proj/w$", (None, "T")),
    (r"mixer/out_proj/w$", ("T", None)),
    (r"mixer/a_log$", ("T", None)),
    (r"mixer/(conv_b|d_skip|dt_bias)$", ("T",)),
    (r"mixer/conv_w$", (None, "T")),
    # RG-LRU
    (r"mixer/(in_x|in_y|gate_a|gate_x)/w$", (None, "T")),
    (r"mixer/out/w$", ("T", None)),
    (r"mixer/lambda_p$", ("T",)),
    # norms: EXPLICITLY replicated — stacked-over-layers scale/bias grow
    # past the large-leaf threshold on deep configs, and an explicit rule
    # keeps unmatched_large_leaves() meaning "rules-table gap", not
    # "known-replicated peripheral"
    (r"norm\d*/(scale|bias)$", ()),
    # adapters (lora_a/lora_b/dora_m) + everything else: replicated
)

CACHE_RULES: Sequence[Tuple[str, Tuple]] = (
    # KV cache (B, L, kvh, hd): shard the SEQUENCE dim over the model axis
    # (flash-decoding style): attention reduces over L, so scores shard
    # cleanly and the per-step collectives are the tiny softmax partials,
    # not cache re-gathers (§Perf H-4; head_dim sharding forced XLA into
    # per-step full-cache resharding copies).
    (r"/(k|v)$", ("D", "T", None, None)),
    (r"/c_kv$", ("D", "T", None)),  # MLA latent cache
    (r"/k_rope$", ("D", "T", None)),
    (r"/h$", ("D", "T", None)),  # SSM state (B, d_inner, N)
    (r"/conv$", ("D", None, "T")),
    (r"/enc_out$", ("D", None, None)),
)
# RG-LRU h is (B, d_rnn) — 2D; the ("D","T",None) rule is trimmed to rank.


_AXES = threading.local()


@contextlib.contextmanager
def logical_axes(dp: Tuple[str, ...], tp: str):
    """Bind logical axis names for shard_hint() inside model code."""
    prev = getattr(_AXES, "val", None)
    _AXES.val = {"D": dp, "T": tp}
    try:
        yield
    finally:
        _AXES.val = prev


def shard_hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint using logical axis names ('D'/'T'/None);
    no-op when no logical axes are bound (smoke tests, CNN repro)."""
    axes = getattr(_AXES, "val", None)
    if axes is None:
        return x
    resolved = []
    for dim, s in zip(x.shape, spec):
        a = axes.get(s) if isinstance(s, str) else None
        resolved.append(_fit(a, dim))
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except Exception:
        return x  # no ambient mesh


def _fit(axis, dim):
    """Drop an axis whose size doesn't divide the dim (needs ambient mesh
    to check; at trace time under jit the mesh is ambient)."""
    if axis is None:
        return None
    mesh = _ambient_mesh()
    if mesh is None:
        return axis
    size = int(np.prod([mesh.shape[a] for a in _as_tuple(axis)]))
    return axis if dim % size == 0 else None


def _ambient_mesh():
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        m = get_abstract()
        return None if m is None or m.empty else m
    try:  # jax 0.4.x: legacy global mesh set by the Mesh context manager
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def _as_tuple(a):
    return a if isinstance(a, tuple) else (a,)


# ---------------------------------------------------------------------------
# tree -> NamedSharding resolution
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def match_rule(rules, path: str) -> Optional[Tuple]:
    """First rule spec whose pattern matches `path`, else None."""
    for pat, spec in rules:
        if re.search(pat, path):
            return spec
    return None


def serve_tp_shardable(path: str, rules=PARAM_RULES) -> bool:
    """True when `path` matches a rule that tensor-parallelises ("T"
    anywhere in the spec). Used by the serve-TP wrap policy to decide
    which prepared leaves to column-shard vs leave replicated."""
    spec = match_rule(rules, path)
    return spec is not None and "T" in spec


def resolve_spec(
    path: str,
    shape: Tuple[int, ...],
    axis_sizes,
    rules=PARAM_RULES,
    *,
    dp: Tuple[str, ...] = ("data",),
    tp: str = "model",
) -> P:
    """Resolve a leaf path+shape to a PartitionSpec against a mapping of
    mesh axis name -> size (a live `mesh.shape` works, as does a plain
    dict — no devices required, so the zoo tests run on one device)."""
    spec = match_rule(rules, path)
    if spec is None:
        return P()  # replicated
    if spec and spec[0] == "EP":
        # expert-parallel preferred: shard E over tp; fall back to
        # the 2D (D, T) layout when E doesn't divide the model axis.
        # Stacked scan bodies carry a leading group axis -> 4D.
        e = shape[-3] if len(shape) >= 3 else 0
        if e and e % axis_sizes[tp] == 0:
            spec = ("T", None, None)
        else:
            spec = (None,) + tuple(spec[1:])
    spec = spec[-len(shape):] if len(spec) > len(shape) else spec
    pad = len(shape) - len(spec)
    axes = [None] * pad + [
        (dp if s == "D" else tp if s == "T" else None) for s in spec
    ]
    # divisibility guard per dim
    out = []
    for dim, a in zip(shape, axes):
        if a is None:
            out.append(None)
            continue
        size = int(np.prod([axis_sizes[x] for x in _as_tuple(a)]))
        out.append(a if dim % size == 0 else None)
    return P(*out)


def unmatched_large_leaves(
    abstract_tree: Pytree,
    *,
    min_size: int = 65536,
    rules=PARAM_RULES,
):
    """Leaf paths with >= min_size elements that match no rule — i.e.
    weights that would silently replicate. Adapter/norm leaves are small
    by design; anything big and unmatched is a rules-table gap."""
    bad = []

    def leaf(path, x):
        p = _path_str(path)
        if int(np.prod(x.shape)) >= min_size and match_rule(rules, p) is None:
            bad.append((p, tuple(x.shape)))

    jax.tree_util.tree_map_with_path(leaf, abstract_tree)
    return bad


def tree_shardings(
    abstract_tree: Pytree,
    mesh: Mesh,
    rules=PARAM_RULES,
    *,
    dp: Tuple[str, ...] = ("data",),
    tp: str = "model",
) -> Pytree:
    def leaf(path, x):
        spec = resolve_spec(_path_str(path), x.shape, mesh.shape, rules, dp=dp, tp=tp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, abstract_tree)


def param_shardings(abstract_params: Pytree, mesh: Mesh, *, dp=("data",), tp="model"):
    return tree_shardings(abstract_params, mesh, PARAM_RULES, dp=dp, tp=tp)


def cache_shardings(abstract_cache: Pytree, mesh: Mesh, *, dp=("data",), tp="model"):
    return tree_shardings(abstract_cache, mesh, CACHE_RULES, dp=dp, tp=tp)


def batch_shardings(abstract_batch: Pytree, mesh: Mesh, *, dp=("data",), tp="model"):
    """Inputs: shard leading batch dim over dp (when divisible)."""

    def leaf(path, x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        size = int(np.prod([mesh.shape[a] for a in dp]))
        first = dp if x.shape[0] % size == 0 else None
        return NamedSharding(mesh, P(first, *([None] * (x.ndim - 1))))

    return jax.tree_util.tree_map_with_path(leaf, abstract_batch)


def replicated(tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
