from repro.sharding.rules import (  # noqa: F401
    param_shardings,
    cache_shardings,
    batch_shardings,
    logical_axes,
    shard_hint,
    tree_shardings,
)
