"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``ARCH: ArchSpec`` with the exact published config
(full) and a reduced same-family smoke config.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.shapes import ArchSpec, ShapeSpec, ALL_SHAPES, input_specs  # noqa

ARCH_IDS: List[str] = [
    "seamless_m4t_large_v2",
    "gemma3_12b",
    "qwen3_1_7b",
    "minitron_8b",
    "deepseek_coder_33b",
    "falcon_mamba_7b",
    "deepseek_v2_lite_16b",
    "mixtral_8x22b",
    "paligemma_3b",
    "recurrentgemma_9b",
]

# public ids with dashes as listed in the assignment
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
ALIASES.update({"qwen3-1.7b": "qwen3_1_7b", "seamless-m4t-large-v2": "seamless_m4t_large_v2"})


def get_arch(name: str) -> ArchSpec:
    key = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.ARCH


def all_archs() -> Dict[str, ArchSpec]:
    return {i: get_arch(i) for i in ARCH_IDS}
