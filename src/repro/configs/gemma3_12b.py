"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

Simplifications noted in DESIGN.md: single rope_theta for local and global
layers (gemma3 uses 10k local / 1M global); pre-norm only (no post-norms).
"""
from repro.configs.shapes import ArchSpec, lm_shapes, FULL_ATTN_SKIP
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b",
    d_model=3840,
    n_layers=48,
    vocab=262144,
    attn=AttentionConfig(
        d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
        rope_theta=1e6,
    ),
    mlp=MlpConfig(d_model=3840, d_ff=15360, gated=True, activation="gelu_tanh"),
    mixer_pattern=("local", "local", "local", "local", "local", "attn"),
    ffn_pattern=("mlp",),
    local_window=1024,
    norm="rms",
    embed_scale=True,
    tie_lm_head=True,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    d_model=64,
    n_layers=6,  # one full 5:1 local:global group
    vocab=512,
    attn=AttentionConfig(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16),
    mlp=MlpConfig(d_model=64, d_ff=128, gated=True, activation="gelu_tanh"),
    mixer_pattern=("local", "local", "local", "local", "local", "attn"),
    local_window=8,
    embed_scale=True,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="gemma3-12b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=False),
    skips={"long_500k": FULL_ATTN_SKIP + " (1-in-6 layers are global)"},
)
