"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — mamba1 arch. [arXiv:2410.05355; unverified]

Attention-free: the DoRA side-cars attach to the SSM projections
(in/x/dt/out) — the paper's technique applies unchanged (DESIGN.md §4).
long_500k RUNS: O(1) recurrent state.
"""
from repro.configs.shapes import ArchSpec, lm_shapes
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.ssm import SsmConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b",
    d_model=4096,
    n_layers=64,
    vocab=65024,
    ssm=SsmConfig(d_model=4096, d_inner=8192, state_dim=16, conv_kernel=4,
                  chunk=256),
    mixer_pattern=("ssm",),
    ffn_pattern=("none",),
    norm="rms",
    tie_lm_head=False,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    ssm=SsmConfig(d_model=64, d_inner=128, state_dim=8, conv_kernel=4, chunk=16),
    mixer_pattern=("ssm",),
    ffn_pattern=("none",),
    tie_lm_head=False,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="falcon-mamba-7b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=True),
    skips={},
)
