"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (MHA kv=16)
d_ff=8192 vocab=256206 — encoder-decoder, multimodal.
[arXiv:2308.11596; hf]

The audio (conformer) frontend is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings to the 24-layer
text/speech encoder; the 24-layer decoder adds cross-attention. "24L" is
read as 24 encoder + 24 decoder (the HF large-v2 layout).
"""
from repro.configs.shapes import ArchSpec, lm_shapes, FULL_ATTN_SKIP
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-large-v2",
    d_model=1024,
    n_layers=24,
    vocab=256206,
    attn=AttentionConfig(
        d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
        rope_theta=10000.0,
    ),
    mlp=MlpConfig(d_model=1024, d_ff=8192, gated=False, activation="gelu"),
    norm="layer",
    tie_lm_head=False,
    encoder_layers=24,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    attn=AttentionConfig(d_model=64, num_heads=4, num_kv_heads=4, head_dim=16),
    mlp=MlpConfig(d_model=64, d_ff=128, gated=False, activation="gelu"),
    norm="layer",
    tie_lm_head=False,
    encoder_layers=2,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="seamless-m4t-large-v2",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=False),
    skips={"long_500k": FULL_ATTN_SKIP},
    enc_src_len=4096,
)
