"""Assigned input-shape sets and abstract input construction.

Every LM arch is paired with the four assigned shapes; ``long_500k`` is
included only for sub-quadratic archs (SSM / hybrid / SWA) — pure
full-attention archs skip it with a recorded reason (DESIGN.md §4).

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins only — no device
allocation ever happens here (dry-run discipline).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """One assigned architecture: exact full config + reduced smoke config
    + its shape cells."""

    name: str
    full: object  # ModelConfig
    smoke: object  # ModelConfig
    # shape name -> ShapeSpec for supported cells
    shapes: Dict[str, ShapeSpec]
    # shape name -> reason string for skipped cells
    skips: Dict[str, str]
    # encoder source length (enc-dec archs): frames provided by the stub
    enc_src_len: int = 0
    # vision prefix tokens provided by the stub (vlm archs)
    notes: str = ""


def lm_shapes(*, subquadratic: bool, decoder: bool = True) -> Dict[str, ShapeSpec]:
    shapes = {"train_4k": TRAIN_4K, "prefill_32k": PREFILL_32K}
    if decoder:
        shapes["decode_32k"] = DECODE_32K
        if subquadratic:
            shapes["long_500k"] = LONG_500K
    return shapes


FULL_ATTN_SKIP = (
    "long_500k skipped: full (quadratic) attention layers — 512k dense KV "
    "cache/attention is out of scope for this arch family (DESIGN.md §4)"
)


def input_specs(arch: ArchSpec, shape: ShapeSpec, *, smoke: bool = False) -> Dict:
    """Abstract inputs for the step lowered for this (arch, shape) cell.

    train/prefill: {tokens (B,S) i32 [, enc_embeds (B,S_src,d)]
                    [, patch_embeds (B,P,d)]}
    decode:        {tokens (B,1) i32, pos scalar i32}
    Cache/abstract-state specs are built separately via jax.eval_shape on
    the model's init_cache (see launch/dryrun.py).
    """
    cfg = arch.smoke if smoke else arch.full
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.encoder_layers:
            src = min(arch.enc_src_len or s, s)
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, src, cfg.d_model), jnp.bfloat16
            )
        if cfg.vision_tokens:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16
            )
        return specs
    # decode: one new token against a cache of length shape.seq_len
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
