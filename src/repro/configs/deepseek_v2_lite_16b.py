"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408(per-expert)
vocab=102400 — MLA kv_lora=512, 2 shared + 64 routed top-6 experts,
first layer dense (d_ff 10944). [arXiv:2405.04434; hf]"""
from repro.configs.shapes import ArchSpec, lm_shapes, FULL_ATTN_SKIP
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.moe import MoeConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    d_model=2048,
    n_layers=27,
    vocab=102400,
    attn=AttentionConfig(
        d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
        rope_theta=10000.0, mla=True, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    ),
    mlp=MlpConfig(d_model=2048, d_ff=10944, gated=True, activation="silu"),
    moe=MoeConfig(
        d_model=2048, d_ff=1408, n_experts=64, top_k=6, n_shared=2,
        capacity_factor=1.25, activation="silu",
    ),
    mixer_pattern=("attn",),
    ffn_pattern=("moe",),
    prologue_layers=1,
    prologue_ffn="mlp",
    norm="rms",
    tie_lm_head=False,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    attn=AttentionConfig(
        d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        mla=True, kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
        v_head_dim=16,
    ),
    mlp=MlpConfig(d_model=64, d_ff=256, gated=True, activation="silu"),
    moe=MoeConfig(d_model=64, d_ff=32, n_experts=4, top_k=2, n_shared=1,
                  capacity_factor=2.0),
    mixer_pattern=("attn",),
    ffn_pattern=("moe",),
    prologue_layers=1,
    prologue_ffn="mlp",
    tie_lm_head=False,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="deepseek-v2-lite-16b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=False),
    skips={"long_500k": FULL_ATTN_SKIP},
)
