"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.shapes import ArchSpec, lm_shapes, FULL_ATTN_SKIP
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.transformer import ModelConfig

_ADAPTER = AdapterConfig(rank=8, kind="dora")
_RRAM = RramConfig(relative_drift=0.10)

FULL = ModelConfig(
    name="qwen3-1.7b",
    d_model=2048,
    n_layers=28,
    vocab=151936,
    attn=AttentionConfig(
        d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
        rope_theta=1e6, qk_norm=True,
    ),
    mlp=MlpConfig(d_model=2048, d_ff=6144, gated=True, activation="silu"),
    mixer_pattern=("attn",),
    ffn_pattern=("mlp",),
    norm="rms",
    tie_lm_head=True,
    adapter=_ADAPTER,
    rram=_RRAM,
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    attn=AttentionConfig(
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, qk_norm=True
    ),
    mlp=MlpConfig(d_model=64, d_ff=128, gated=True, activation="silu"),
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="qwen3-1.7b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=False),
    skips={"long_500k": FULL_ATTN_SKIP},
)
