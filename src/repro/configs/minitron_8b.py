"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron (squared-ReLU MLP, untied head).
[arXiv:2407.14679; hf]"""
from repro.configs.shapes import ArchSpec, lm_shapes, FULL_ATTN_SKIP
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="minitron-8b",
    d_model=4096,
    n_layers=32,
    vocab=256000,
    attn=AttentionConfig(
        d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
        rope_theta=10000.0,
    ),
    mlp=MlpConfig(d_model=4096, d_ff=16384, gated=False, activation="relu"),
    norm="layer",
    tie_lm_head=False,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="minitron-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    attn=AttentionConfig(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16),
    mlp=MlpConfig(d_model=64, d_ff=128, gated=False, activation="relu"),
    norm="layer",
    tie_lm_head=False,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="minitron-8b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=False),
    skips={"long_500k": FULL_ATTN_SKIP},
)
