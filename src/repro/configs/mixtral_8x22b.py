"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, SWA. [arXiv:2401.04088; hf]

Sliding-window attention (window 4096) bounds the KV cache ->
long_500k RUNS with a rolling window cache.
"""
from repro.configs.shapes import ArchSpec, lm_shapes
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.moe import MoeConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x22b",
    d_model=6144,
    n_layers=56,
    vocab=32768,
    attn=AttentionConfig(
        d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
        rope_theta=1e6,
    ),
    moe=MoeConfig(
        d_model=6144, d_ff=16384, n_experts=8, top_k=2, n_shared=0,
        capacity_factor=1.25, activation="silu",
    ),
    mixer_pattern=("swa",),
    ffn_pattern=("moe",),
    local_window=4096,
    norm="rms",
    tie_lm_head=False,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    attn=AttentionConfig(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16),
    moe=MoeConfig(d_model=64, d_ff=128, n_experts=4, top_k=2, n_shared=0,
                  capacity_factor=2.0),
    mixer_pattern=("swa",),
    ffn_pattern=("moe",),
    local_window=16,
    tie_lm_head=False,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="mixtral-8x22b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=True),
    skips={},
    notes="long_500k runs: SWA rolling cache bounds memory at window=4096",
)
