"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn, pattern (rglru, rglru, local).
[arXiv:2402.19427; unverified]

long_500k RUNS: recurrent state is O(1) and local attention uses a
rolling window-2048 cache. 38 layers = 12 x (rglru,rglru,local) + 2
epilogue rglru layers.
"""
from repro.configs.shapes import ArchSpec, lm_shapes
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.rglru import RglruConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    d_model=4096,
    n_layers=38,
    vocab=256000,
    attn=AttentionConfig(
        d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
        rope_theta=10000.0,
    ),
    mlp=MlpConfig(d_model=4096, d_ff=12288, gated=True, activation="gelu_tanh"),
    rglru=RglruConfig(d_model=4096, d_rnn=4096, conv_kernel=4),
    mixer_pattern=("rglru", "rglru", "local"),
    ffn_pattern=("mlp",),
    local_window=2048,
    norm="rms",
    embed_scale=True,
    tie_lm_head=True,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    d_model=64,
    n_layers=8,  # 2 groups + 2 epilogue, mirrors the 38-layer remainder
    vocab=512,
    attn=AttentionConfig(d_model=64, num_heads=4, num_kv_heads=1, head_dim=16),
    mlp=MlpConfig(d_model=64, d_ff=128, gated=True, activation="gelu_tanh"),
    rglru=RglruConfig(d_model=64, d_rnn=64, conv_kernel=4),
    mixer_pattern=("rglru", "rglru", "local"),
    local_window=8,
    embed_scale=True,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="recurrentgemma-9b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=True),
    skips={},
)
