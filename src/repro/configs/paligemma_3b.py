"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma. [arXiv:2407.07726; hf]

The SigLIP vision tower is a STUB per the assignment: ``input_specs``
provides 256 precomputed patch embeddings which attend as a bidirectional
prefix (prefix-LM masking); the gemma text backbone is fully modeled.
"""
from repro.configs.shapes import ArchSpec, lm_shapes, FULL_ATTN_SKIP
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="paligemma-3b",
    d_model=2048,
    n_layers=18,
    vocab=257216,
    attn=AttentionConfig(
        d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
        rope_theta=10000.0,
    ),
    mlp=MlpConfig(d_model=2048, d_ff=16384, gated=True, activation="gelu_tanh"),
    norm="rms",
    embed_scale=True,
    tie_lm_head=True,
    vision_tokens=256,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="paligemma-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    attn=AttentionConfig(d_model=64, num_heads=4, num_kv_heads=1, head_dim=16),
    mlp=MlpConfig(d_model=64, d_ff=128, gated=True, activation="gelu_tanh"),
    embed_scale=True,
    vision_tokens=8,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="paligemma-3b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=False),
    skips={"long_500k": FULL_ATTN_SKIP},
)
