"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch. [arXiv:2401.14196; hf]"""
from repro.configs.shapes import ArchSpec, lm_shapes, FULL_ATTN_SKIP
from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig
from repro.models.transformer import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b",
    d_model=7168,
    n_layers=62,
    vocab=32256,
    attn=AttentionConfig(
        d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
        rope_theta=100000.0,
    ),
    mlp=MlpConfig(d_model=7168, d_ff=19200, gated=True, activation="silu"),
    norm="rms",
    tie_lm_head=False,
    adapter=AdapterConfig(rank=8, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
)

SMOKE = ModelConfig(
    name="deepseek-coder-smoke",
    d_model=64,
    n_layers=4,
    vocab=512,
    attn=AttentionConfig(d_model=64, num_heads=8, num_kv_heads=2, head_dim=8),
    mlp=MlpConfig(d_model=64, d_ff=160, gated=True, activation="silu"),
    tie_lm_head=False,
    adapter=AdapterConfig(rank=4, kind="dora"),
    rram=RramConfig(relative_drift=0.10),
    remat=False,
)

ARCH = ArchSpec(
    name="deepseek-coder-33b",
    full=FULL,
    smoke=SMOKE,
    shapes=lm_shapes(subquadratic=False),
    skips={"long_500k": FULL_ATTN_SKIP},
)
