from repro.checkpoint.manager import CheckpointManager, as_manager  # noqa: F401
