"""Checkpoint manager: atomic commits, retention, async writes, and
mesh-resharding restore.

Design for 1000+ nodes (DESIGN.md §6):

* **Atomicity** — write to ``<dir>/tmp.<step>/`` then ``os.rename`` to
  ``step_<n>/``; a crash mid-write never corrupts the latest checkpoint
  (rename is atomic on POSIX).
* **Async** — ``save(..., blocking=False)`` hands the host-side arrays to
  a writer thread; training continues (adapters are 2.3 % of params, so
  the host copy is cheap — this is a concrete payoff of the paper's
  technique at scale: checkpoint traffic shrinks by the same 42x).
* **What's saved** — adapters + optimizer state + step every time
  (``save_adapters``); the static teacher/student bases are saved once at
  deployment (``save_base``). Drift is deterministic given the programming
  key (core/calibrate.py), so the student base can alternatively be
  re-derived on restore — both paths are supported and tested.
* **Resharding restore** — arrays are saved UNSHARDED (gathered); restore
  places them onto any mesh via ``jax.device_put`` with the target
  sharding, so an elastic (15,16) mesh or a (2,16,16) multi-pod mesh can
  load a (16,16) checkpoint unchanged.

Storage format: one ``.npz`` per pytree + a JSON treedef manifest (no
external deps; for real clusters swap the io layer for a parallel store —
the interface is 3 functions).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten_with_names(tree: Pytree) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        named.append((name, np.asarray(leaf)))
    return named, treedef


def as_manager(directory_or_manager, *, keep: int = 3) -> "CheckpointManager":
    """Coerce a path-or-manager argument to a ``CheckpointManager``.

    Every persistence entry point (``Deployment.snapshot/restore``,
    ``Fleet.snapshot/restore``, the calibration registry's artifact
    store) accepts either an existing manager or a directory; this is
    the one place that coercion lives. ``keep`` only applies when a new
    manager is constructed — an existing manager keeps its own policy.
    """
    if isinstance(directory_or_manager, CheckpointManager):
        return directory_or_manager
    return CheckpointManager(str(directory_or_manager), keep=keep)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(
        self, step: int, trees: Dict[str, Pytree], *, blocking: bool = True
    ) -> None:
        """Save named pytrees for ``step``. Gathers to host first (cheap:
        callers pass adapters/opt-state, not the frozen bases)."""
        host_trees = {
            name: jax.tree_util.tree_map(lambda x: np.asarray(x), t)
            for name, t in trees.items()
        }
        if blocking:
            self._write(step, host_trees)
        else:
            self._ensure_worker()
            self._queue.put((step, host_trees))

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._queue.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

    def wait(self):
        """Block until queued async saves are on disk (and re-raise any
        writer error)."""
        if self._worker is not None and self._worker.is_alive():
            self._queue.put(None)
            self._worker.join()
            self._worker = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, host_trees: Dict[str, Pytree]):
        tmp = os.path.join(self.directory, f"tmp.{step}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "trees": {}}
        for name, tree in host_trees.items():
            named, treedef = _flatten_with_names(tree)
            arrays = {f"a{i}": arr for i, (_, arr) in enumerate(named)}
            np.savez(os.path.join(tmp, f"{name}.npz"), **arrays)
            manifest["trees"][name] = {
                "leaf_names": [n for n, _ in named],
                "treedef": str(treedef),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"))

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_"):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        like: Dict[str, Pytree],
        *,
        shardings: Optional[Dict[str, Pytree]] = None,
    ) -> Dict[str, Pytree]:
        """Restore named pytrees; ``like`` provides structure/dtypes.
        ``shardings`` (same structure) places leaves onto a target mesh —
        THIS is the resharding path: the saved arrays are mesh-agnostic.
        """
        d = os.path.join(self.directory, f"step_{step:010d}")
        out = {}
        for name, ref_tree in like.items():
            data = np.load(os.path.join(d, f"{name}.npz"))
            leaves_ref, treedef = jax.tree_util.tree_flatten(ref_tree)
            arrays = [data[f"a{i}"] for i in range(len(leaves_ref))]
            arrays = [
                a.astype(r.dtype) if hasattr(r, "dtype") else a
                for a, r in zip(arrays, leaves_ref)
            ]
            if shardings is not None:
                sh_leaves = jax.tree_util.tree_leaves(shardings[name])
                arrays = [
                    jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)
                ]
            out[name] = jax.tree_util.tree_unflatten(treedef, arrays)
        return out
