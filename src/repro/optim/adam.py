"""AdamW over an arbitrary pytree (no optax dependency).

In this framework the optimizer only ever sees the **adapter** sub-pytree —
2.3 % of model parameters (paper Table I) — so optimizer memory is
proportionally tiny. State is kept in f32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0


class AdamState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


def adamw_init(params: Pytree) -> AdamState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    ]
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Pytree, state: AdamState, params: Pytree, cfg: AdamW
):
    """Returns (new_params, new_state)."""
    step = state.step + 1
    if cfg.grad_clip is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * scale, grads
        )
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    mu = jax.tree_util.tree_map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads
    )
    nu = jax.tree_util.tree_map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.nu, grads
    )
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, m, v):
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * update).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)
