"""Error-feedback int8 gradient compression for data-parallel all-reduce.

At 1000+ node scale the DP all-reduce of adapter gradients is latency-
sensitive (adapters are small, so the reduction is latency- not bandwidth-
bound — but on shared ICI/DCN links compressing 4x still matters when
calibration steps are short). We quantize each gradient leaf to int8 with a
per-leaf scale before ``psum`` and keep the quantization residual locally,
adding it back the next step (error feedback guarantees the compressed SGD
trajectory tracks the exact one; Karimireddy et al. 2019).

Usage (inside shard_map over the data axes):

    residual = init_residual(grads)            # once, before the loop
    ...
    grads, residual = allreduce_compressed(grads, residual, axis_name)

or, driving the pieces by hand (``compress`` returns a 3-tuple — the
per-leaf scales travel with the codes):

    codes, scales, residual = compress(grads, residual)
    grads = tree_map(
        lambda c, s: jax.lax.psum(c.astype(f32) * s, axis_name) / n_shards,
        codes, scales,
    )
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def compress(grads: Pytree, residual: Pytree) -> Tuple[Pytree, Pytree, Pytree]:
    """Returns (int8 codes, scales, new residual)."""

    def one(g, r):
        g = g.astype(jnp.float32) + r
        absmax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
        scale = absmax / 127.0
        codes = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_r = g - codes.astype(jnp.float32) * scale
        return codes, scale, new_r

    flat, treedef = jax.tree_util.tree_flatten(grads)
    rflat = jax.tree_util.tree_leaves(residual)
    codes, scales, new_r = [], [], []
    for g, r in zip(flat, rflat):
        c, s, nr = one(g, r)
        codes.append(c)
        scales.append(s)
        new_r.append(nr)
    unflatten = treedef.unflatten
    return unflatten(codes), unflatten(scales), unflatten(new_r)


def allreduce_compressed(
    grads: Pytree, residual: Pytree, axis_name
) -> Tuple[Pytree, Pytree]:
    """psum int8 codes (as f32) and rescale: mean of dequantized grads.
    Must run inside shard_map/pmap with ``axis_name`` bound."""
    codes, scales, new_residual = compress(grads, residual)
    n = jax.lax.psum(1, axis_name)

    def reduce_one(c, s):
        # each shard contributes codes*scale; sum then average
        contrib = c.astype(jnp.float32) * s
        return jax.lax.psum(contrib, axis_name) / n

    reduced = jax.tree_util.tree_map(reduce_one, codes, scales)
    return reduced, new_residual


def init_residual(grads_like: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )
