from repro.optim.adam import AdamW, adamw_init, adamw_update  # noqa: F401
