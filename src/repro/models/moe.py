"""Mixture-of-Experts FFN (mixtral-8x22b, deepseek-v2-lite).

Dispatch strategy (TPU/pjit-native, no torch.distributed emulation):
tokens are grouped by expert via an argsort permutation into a fixed
``(n_experts, capacity)`` layout, expert FFNs run as one batched einsum
``(E, C, d) x (E, d, ff)``, and results scatter-add back weighted by the
router gates. Expert weights are sharded tensor-parallel over the "model"
axis along ``d_ff`` (every device holds a slice of every expert), so
dispatch needs **no all-to-all** — the activation stays data-sharded and
the expert einsum reduces over the model axis like a dense MLP.
(Expert-parallel dispatch is an explored hillclimb alternative; see
EXPERIMENTS.md §Perf.)

The router is itself a RimcLinear — its weights drift in RRAM and receive
a DoRA side-car like every other projection (routing drift is a real
failure mode the paper's technique must fix; tests/test_moe.py checks it).

Overflowing tokens beyond capacity are dropped (standard Switch-style);
with ``capacity_factor >= top_k * n_experts / n_experts`` and uniform
routing the drop rate is ~0. Dropped tokens fall back to the shared
experts/residual path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dora
from repro.core.dora import AdapterConfig
from repro.core.rram import CrossbarWeight, dequantize
from repro.models import layers as L
from repro.sharding.rules import shard_hint


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int  # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0  # always-on shared experts (deepseek-v2)
    capacity_factor: float = 1.25
    activation: str = "silu"
    # routed scaling (deepseek multiplies routed output)
    routed_scale: float = 1.0


def init_moe(
    key: jax.Array, cfg: MoeConfig, acfg: AdapterConfig, dtype=jnp.bfloat16
) -> Tuple[Dict, Dict]:
    keys = jax.random.split(key, 5)
    base: Dict = {}
    adapters: Dict = {}
    base["router"], adapters["router"] = L.init_linear(
        keys[0], cfg.d_model, cfg.n_experts, acfg, dtype=jnp.float32
    )
    scale_in = cfg.d_model ** -0.5
    scale_out = cfg.d_ff ** -0.5

    def expert_stack(k, d_in, d_out, scale):
        return (
            jax.random.normal(k, (cfg.n_experts, d_in, d_out), jnp.float32) * scale
        ).astype(dtype)

    base["gate_w"] = expert_stack(keys[1], cfg.d_model, cfg.d_ff, scale_in)
    base["up_w"] = expert_stack(keys[2], cfg.d_model, cfg.d_ff, scale_in)
    base["down_w"] = expert_stack(keys[3], cfg.d_ff, cfg.d_model, scale_out)
    # Per-expert DoRA side-cars, stacked on the expert axis.
    ka = jax.random.split(keys[4], 3)
    adapters["gate_w"] = _stacked_adapter(ka[0], cfg.n_experts, cfg.d_model, cfg.d_ff, acfg, base["gate_w"])
    adapters["up_w"] = _stacked_adapter(ka[1], cfg.n_experts, cfg.d_model, cfg.d_ff, acfg, base["up_w"])
    adapters["down_w"] = _stacked_adapter(ka[2], cfg.n_experts, cfg.d_ff, cfg.d_model, acfg, base["down_w"])
    if cfg.n_shared:
        kg = jax.random.split(keys[4], cfg.n_shared + 3)[3:]
        shared_base, shared_ad = [], []
        mcfg = L.MlpConfig(cfg.d_model, cfg.d_ff * cfg.n_shared, gated=True,
                           activation=cfg.activation)
        sb, sa = L.init_mlp(kg[0], mcfg, acfg, dtype=dtype)
        base["shared"] = sb
        adapters["shared"] = sa
    return base, adapters


def _stacked_adapter(key, n_experts, d, k, acfg: AdapterConfig, w_stack):
    if acfg.kind == "none":
        return {}
    keys = jax.random.split(key, n_experts)
    ads = [
        dora.init_adapter(keys[e], d, k, acfg, w_base=w_stack[e])
        for e in range(n_experts)
    ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ads)


def _expert_matmul(
    x: jax.Array,  # (B, E, C, d_in)
    w: jax.Array,  # (E, d_in, d_out) float — or a stacked CrossbarWeight
    adapter: Optional[Dict],
    acfg: AdapterConfig,
) -> jax.Array:
    if isinstance(w, CrossbarWeight):
        # codes-resident expert stack: HBM holds the uint8 (G+, G-) pairs;
        # the differential dequant happens on the fly inside this call
        # (XLA fuses it into the einsum — the stacked-expert analogue of
        # the fused kernel's in-register dequant).
        w = dequantize(w, dtype=x.dtype)
    y = jnp.einsum("becd,edf->becf", x, w.astype(x.dtype))
    if not adapter:
        return y
    a = adapter["lora_a"].astype(x.dtype)  # (E, d_in, r)
    b = adapter["lora_b"].astype(x.dtype)  # (E, r, d_out)
    y = y + jnp.einsum(
        "becr,erf->becf", jnp.einsum("becd,edr->becr", x, a), b
    )
    if acfg.kind == "dora":
        if "dora_m_merged" in adapter:
            scale = adapter["dora_m_merged"].astype(jnp.float32)
        else:
            norm = _stacked_column_norm(w, adapter["lora_a"], adapter["lora_b"])
            scale = adapter["dora_m"].astype(jnp.float32) / norm
        y = y * scale[None, :, None, :].astype(x.dtype)
    return y


def _stacked_column_norm(w, a, b, eps=1e-6):
    if isinstance(w, CrossbarWeight):
        w = dequantize(w)
    wf = w.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    w_sq = jnp.sum(wf * wf, axis=1)  # (E, d_out)
    wta = jnp.einsum("edk,edr->ekr", wf, af)  # (E, d_out, r)
    cross = jnp.einsum("ekr,erk->ek", wta, bf)
    ab = jnp.einsum("edr,erk->edk", af, bf)
    ab_sq = jnp.sum(ab * ab, axis=1)
    return jnp.sqrt(jnp.maximum(w_sq + 2.0 * cross + ab_sq, eps))


def _route_row(
    xrow: jax.Array,  # (S, d) one batch row's tokens
    router_logits: jax.Array,  # (S, E)
    cfg: MoeConfig,
    capacity: int,
):
    """Group ONE batch row's tokens into (E*C,) slots.

    Dispatch granularity is the batch row, so with the batch dim sharded
    over the data axes the argsort/scatter never crosses shards — the
    global-argsort variant replicated the full (T, d) token set on every
    device (5 TB/step of all-gather on deepseek-v2 train_4k; see
    EXPERIMENTS.md §Perf H-1)."""
    s = xrow.shape[0]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, cfg.top_k)  # (S, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    flat_expert = expert_idx.reshape(-1)  # (S*k,)
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    pos_in_group = jnp.arange(s * cfg.top_k) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left"
    )
    keep = pos_in_group < capacity
    slot = jnp.where(
        keep, sorted_expert * capacity + pos_in_group,
        cfg.n_experts * capacity,
    )
    token_of_entry = order // cfg.top_k
    slot_token = jnp.full((cfg.n_experts * capacity + 1,), s, jnp.int32)
    slot_token = slot_token.at[slot].set(token_of_entry.astype(jnp.int32))
    slot_gate = jnp.zeros((cfg.n_experts * capacity + 1,), jnp.float32)
    slot_gate = slot_gate.at[slot].set(gates.reshape(-1)[order])
    return slot_token[:-1], slot_gate[:-1]  # (E*C,), (E*C,)


def moe_block(
    x: jax.Array,  # (B, S, d)
    base: Dict,
    adapters: Optional[Dict],
    cfg: MoeConfig,
    acfg: AdapterConfig,
) -> jax.Array:
    a_ = adapters or {}
    bsz, s, d = x.shape
    if s == 1:
        # decode: dense gating — every expert runs on the single token and
        # results are gate-masked. No dispatch gather/scatter (and so no
        # dispatch collectives); decode is weight-memory-bound, so the
        # top_k/E extra FLOPs are below the roofline anyway (§Perf H-3).
        return _moe_decode_dense(x, base, a_, cfg, acfg)
    capacity = int(
        max(1, -(-s * cfg.top_k * cfg.capacity_factor // cfg.n_experts))
    )

    # --- routing + per-row grouping (data-local; no cross-shard movement) ---
    logits = L.linear(
        x.astype(jnp.float32), base["router"], a_.get("router"), acfg
    )  # (B, S, E)
    slot_token, slot_gate = jax.vmap(
        lambda xr, lr: _route_row(xr, lr, cfg, capacity)
    )(x, logits)  # (B, E*C) each

    x_pad = jnp.concatenate([x, jnp.zeros((bsz, 1, d), x.dtype)], axis=1)
    xg = jnp.take_along_axis(
        x_pad, slot_token[..., None].astype(jnp.int32), axis=1
    ).reshape(bsz, cfg.n_experts, capacity, d)
    xg = shard_hint(xg, "D", None, None, None)

    # --- expert FFNs ---------------------------------------------------------
    gate_h = shard_hint(
        _expert_matmul(xg, base["gate_w"], a_.get("gate_w"), acfg),
        "D", None, None, "T",
    )
    up_h = shard_hint(
        _expert_matmul(xg, base["up_w"], a_.get("up_w"), acfg),
        "D", None, None, "T",
    )
    h = L._act(gate_h, cfg.activation) * up_h
    out_g = shard_hint(
        _expert_matmul(h, base["down_w"], a_.get("down_w"), acfg),
        "D", None, None, None,
    )

    # --- combine (per-row scatter-add, data-local) ---------------------------
    out_flat = out_g.reshape(bsz, cfg.n_experts * capacity, d).astype(jnp.float32)
    out_flat = out_flat * slot_gate[..., None]
    combined = jnp.zeros((bsz, s + 1, d), jnp.float32)
    combined = jax.vmap(lambda c, idx, v: c.at[idx].add(v))(
        combined, slot_token, out_flat
    )
    y = combined[:, :s] * cfg.routed_scale

    # --- shared experts ------------------------------------------------------
    if cfg.n_shared:
        mcfg = L.MlpConfig(
            cfg.d_model, cfg.d_ff * cfg.n_shared, gated=True, activation=cfg.activation
        )
        y = y + L.mlp(x, base["shared"], a_.get("shared"), mcfg, acfg).astype(
            jnp.float32
        )
    return y.astype(x.dtype)


def _moe_decode_dense(x, base, a_, cfg: MoeConfig, acfg):
    bsz, s, d = x.shape  # s == 1
    logits = L.linear(
        x.astype(jnp.float32), base["router"], a_.get("router"), acfg
    )[:, 0]  # (B, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, cfg.top_k)  # (B, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # dense per-expert mask: (B, E) combine weights (0 off the top-k)
    combine = jnp.zeros_like(probs).at[
        jnp.arange(bsz)[:, None], expert_idx
    ].set(gates)
    xe = x[:, None, :, :]  # (B, 1, 1, d) broadcast over experts via einsum
    xg = jnp.broadcast_to(xe, (bsz, cfg.n_experts, 1, d))
    gate_h = _expert_matmul(xg, base["gate_w"], a_.get("gate_w"), acfg)
    up_h = _expert_matmul(xg, base["up_w"], a_.get("up_w"), acfg)
    h = L._act(gate_h, cfg.activation) * up_h
    out_g = _expert_matmul(h, base["down_w"], a_.get("down_w"), acfg)
    # (B, E, 1, d) x (B, E) -> (B, 1, d)
    y = jnp.einsum(
        "beld,be->bld", out_g.astype(jnp.float32), combine
    ) * cfg.routed_scale
    if cfg.n_shared:
        mcfg = L.MlpConfig(
            cfg.d_model, cfg.d_ff * cfg.n_shared, gated=True,
            activation=cfg.activation,
        )
        y = y + L.mlp(x, base["shared"], a_.get("shared"), mcfg, acfg).astype(
            jnp.float32
        )
    return y.astype(x.dtype)


def load_balancing_loss(logits: jax.Array, expert_idx: jax.Array, n_experts: int):
    """Switch-style aux loss (exposed for pre-deployment training; the
    calibration step never trains the router beyond its DoRA side-car)."""
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(expert_idx[..., 0], n_experts)
    usage = jnp.mean(one_hot, axis=0)
    return n_experts * jnp.sum(density * usage)
