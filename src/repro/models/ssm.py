"""Mamba-1 selective SSM block (falcon-mamba-7b).

All dense projections (in/x/dt/out) are RimcLinear — the paper's DoRA
side-car applies to the SSM family exactly as to attention (DESIGN.md §4).
The A_log/D/conv parameters are per-channel "peripheral" parameters
(digital, frozen during calibration, like norm scales).

The selective scan is computed chunk-parallel: ``lax.scan`` carries the
(d_inner, state) SSM state across chunks while an ``associative_scan``
parallelizes within a chunk — the TPU-friendly analogue of Mamba's
hardware-aware fused scan. ``kernels/selective_scan.py`` provides the
Pallas fast path; this file is the reference semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dora import AdapterConfig
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    d_model: int
    d_inner: int  # typically 2 * d_model
    state_dim: int = 16
    conv_kernel: int = 4
    dt_rank: int = 0  # 0 -> d_model // 16
    chunk: int = 128  # within-chunk parallel scan size

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)


def init_ssm(
    key: jax.Array, cfg: SsmConfig, acfg: AdapterConfig, dtype=jnp.bfloat16
) -> Tuple[Dict, Dict]:
    keys = jax.random.split(key, 6)
    base: Dict = {}
    adapters: Dict = {}
    # in_proj produces both the SSM stream x and the gate z.
    base["in_proj"], adapters["in_proj"] = L.init_linear(
        keys[0], cfg.d_model, 2 * cfg.d_inner, acfg, dtype=dtype
    )
    base["x_proj"], adapters["x_proj"] = L.init_linear(
        keys[1], cfg.d_inner, cfg.dt_rank_ + 2 * cfg.state_dim, acfg, dtype=dtype
    )
    base["dt_proj"], adapters["dt_proj"] = L.init_linear(
        keys[2], cfg.dt_rank_, cfg.d_inner, acfg, dtype=dtype
    )
    base["out_proj"], adapters["out_proj"] = L.init_linear(
        keys[3], cfg.d_inner, cfg.d_model, acfg, dtype=dtype
    )
    # peripherals (digital, frozen)
    base["conv_w"] = (
        jax.random.normal(keys[4], (cfg.conv_kernel, cfg.d_inner), jnp.float32)
        * (cfg.conv_kernel ** -0.5)
    ).astype(jnp.float32)
    base["conv_b"] = jnp.zeros((cfg.d_inner,), jnp.float32)
    # S4D-real init: A = -(1..N) per channel
    a_init = jnp.tile(
        jnp.arange(1, cfg.state_dim + 1, dtype=jnp.float32)[None, :],
        (cfg.d_inner, 1),
    )
    base["a_log"] = jnp.log(a_init)
    base["d_skip"] = jnp.ones((cfg.d_inner,), jnp.float32)
    base["dt_bias"] = jnp.log(
        jnp.exp(
            jax.random.uniform(keys[5], (cfg.d_inner,), jnp.float32, 1e-3, 1e-1)
        )
        - 1.0
        + 1e-9
    )
    return base, adapters


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (K, C).

    Accumulates in f32 and rounds once to ``x.dtype`` — the decode paths
    (ssm_decode / rglru_decode) compute this window in f32, so a bf16
    accumulation here would make prefill and decode diverge by an extra
    rounding per tap (the recurrent gates amplify that across the
    sequence; tests/test_models.py::test_decode_matches_forward).
    """
    k = w.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    # windowed sum: sum_j w[j] * x[t - (K-1) + j]
    out = jnp.zeros(x.shape, jnp.float32)
    for j in range(k):
        out = out + xp[:, j : j + x.shape[1], :] * w[j][None, None, :].astype(
            jnp.float32
        )
    return (out + b[None, None, :].astype(jnp.float32)).astype(x.dtype)


def _ssm_params(x: jax.Array, base, a, cfg: SsmConfig, acfg):
    """Input-dependent dt, B, C (selection mechanism)."""
    proj = L.linear(x, base["x_proj"], a.get("x_proj"), acfg)
    dt_low = proj[..., : cfg.dt_rank_]
    b_sel = proj[..., cfg.dt_rank_ : cfg.dt_rank_ + cfg.state_dim]
    c_sel = proj[..., cfg.dt_rank_ + cfg.state_dim :]
    dt = L.linear(dt_low, base["dt_proj"], a.get("dt_proj"), acfg)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + base["dt_bias"][None, None, :]
    )
    return dt, b_sel.astype(jnp.float32), c_sel.astype(jnp.float32)


def selective_scan(
    x: jax.Array,  # (B, S, d_inner)
    dt: jax.Array,  # (B, S, d_inner) f32
    a_log: jax.Array,  # (d_inner, N)
    b_sel: jax.Array,  # (B, S, N)
    c_sel: jax.Array,  # (B, S, N)
    d_skip: jax.Array,  # (d_inner,)
    chunk: int = 128,
    h0: Optional[jax.Array] = None,  # (B, d_inner, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked parallel selective scan. Returns (y, h_final)."""
    bsz, s, d = x.shape
    n = a_log.shape[-1]
    neg_a = -jnp.exp(a_log.astype(jnp.float32))  # (d, N)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_sel = jnp.pad(b_sel, ((0, 0), (0, pad), (0, 0)))
        c_sel = jnp.pad(c_sel, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xs = x.reshape(bsz, nc, chunk, d).astype(jnp.float32)
    dts = dt.reshape(bsz, nc, chunk, d)
    bs = b_sel.reshape(bsz, nc, chunk, n)
    cs = c_sel.reshape(bsz, nc, chunk, n)
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    def chunk_step(h_in, inp):
        xc, dtc, bc, cc = inp  # (B, chunk, ...)
        a_t = jnp.exp(dtc[..., None] * neg_a[None, None])  # (B,c,d,N)
        b_t = (dtc * xc)[..., None] * bc[:, :, None, :]  # (B,c,d,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        a_cum, b_cum = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
        h = a_cum * h_in[:, None] + b_cum  # (B,c,d,N)
        y = jnp.einsum("bcdn,bcn->bcd", h, cc)
        return h[:, -1], y

    h_fin, ys = jax.lax.scan(
        chunk_step,
        h0,
        (
            jnp.moveaxis(xs, 1, 0),
            jnp.moveaxis(dts, 1, 0),
            jnp.moveaxis(bs, 1, 0),
            jnp.moveaxis(cs, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s + pad, d)[:, :s]
    y = y + x[:, :s].astype(jnp.float32) * d_skip[None, None]
    return y, h_fin


def conv_tail(x: jax.Array, kernel: int, dtype=jnp.float32) -> jax.Array:
    """Last ``kernel - 1`` positions of a conv-branch input (zero-padded
    on the left for short sequences) — the rolling conv window a decode
    cache carries after a full-sequence prefill."""
    k = kernel - 1
    b, s, c = x.shape
    if s >= k:
        tail = x[:, s - k :]
    else:
        tail = jnp.concatenate(
            [jnp.zeros((b, k - s, c), x.dtype), x], axis=1
        )
    return tail.astype(dtype)


def ssm_block(
    x: jax.Array,  # (B, S, d_model)
    base: Dict,
    adapters: Optional[Dict],
    cfg: SsmConfig,
    acfg: AdapterConfig,
    *,
    return_state: bool = False,
) -> jax.Array:
    a = adapters or {}
    xz = L.linear(x, base["in_proj"], a.get("in_proj"), acfg)
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs = _causal_conv(xs_raw, base["conv_w"], base["conv_b"])
    xs = jax.nn.silu(xs)
    dt, b_sel, c_sel = _ssm_params(xs, base, a, cfg, acfg)
    y, h_fin = selective_scan(
        xs, dt, base["a_log"], b_sel, c_sel, base["d_skip"], cfg.chunk
    )
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = L.linear(y, base["out_proj"], a.get("out_proj"), acfg)
    if return_state:
        return out, {"h": h_fin, "conv": conv_tail(xs_raw, cfg.conv_kernel)}
    return out


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def init_ssm_cache(batch: int, cfg: SsmConfig, dtype=jnp.float32) -> Dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
    }


def ssm_decode(
    x: jax.Array,  # (B, 1, d_model)
    cache: Dict,
    base: Dict,
    adapters: Optional[Dict],
    cfg: SsmConfig,
    acfg: AdapterConfig,
) -> Tuple[jax.Array, Dict]:
    a = adapters or {}
    xz = L.linear(x, base["in_proj"], a.get("in_proj"), acfg)
    xs, z = jnp.split(xz, 2, axis=-1)  # (B,1,d_inner)
    # conv over the cached window + current input
    window = jnp.concatenate([cache["conv"], xs.astype(cache["conv"].dtype)], axis=1)
    w = base["conv_w"]
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), w
    ) + base["conv_b"]
    xs1 = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)  # (B,1,d_inner)
    dt, b_sel, c_sel = _ssm_params(xs1, base, a, cfg, acfg)
    neg_a = -jnp.exp(base["a_log"].astype(jnp.float32))
    dt0 = dt[:, 0]  # (B, d)
    a_t = jnp.exp(dt0[..., None] * neg_a[None])  # (B,d,N)
    b_t = (dt0 * xs1[:, 0].astype(jnp.float32))[..., None] * b_sel[:, 0, None, :]
    h = a_t * cache["h"] + b_t
    y = jnp.einsum("bdn,bn->bd", h, c_sel[:, 0])
    y = y + xs1[:, 0].astype(jnp.float32) * base["d_skip"][None]
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    out = L.linear(y, base["out_proj"], a.get("out_proj"), acfg)
    new_cache = {"h": h, "conv": window[:, 1:]}
    return out, new_cache
