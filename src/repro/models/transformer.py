"""Unified model assembly for all assigned architectures.

One ``ModelConfig`` describes dense/GQA/MLA attention stacks, local:global
mixes, sliding-window, MoE FFNs, Mamba SSM stacks, RG-LRU hybrids,
encoder-decoder (audio frontend stub) and prefix-LM VLMs (vision stub).

Layer layout: ``prologue`` (unrolled, heterogeneous) + ``body`` (layers
stacked and run under ``jax.lax.scan`` in groups of ``scan_period`` to keep
HLO size / compile time bounded at 512-way SPMD) + ``epilogue`` (unrolled
remainder).

Every projection is a RimcLinear (drifted RRAM base + DoRA side-car);
norms/embeddings are digital peripherals (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dora import AdapterConfig
from repro.core.rram import RramConfig, DEFAULT_RRAM
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S

Pytree = Any

MIXER_KINDS = ("attn", "local", "swa", "ssm", "rglru")
FFN_KINDS = ("mlp", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    vocab: int
    attn: Optional[A.AttentionConfig] = None
    mlp: Optional[L.MlpConfig] = None
    moe: Optional[M.MoeConfig] = None
    ssm: Optional[S.SsmConfig] = None
    rglru: Optional[R.RglruConfig] = None
    # mixer pattern cycled over non-prologue layers, e.g. 5*("local",)+("attn",)
    mixer_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 1024
    # ffn pattern cycled likewise ("mlp" | "moe" | "none")
    ffn_pattern: Tuple[str, ...] = ("mlp",)
    # number of initial layers with ``prologue_ffn`` instead (deepseek-v2's
    # dense first layer)
    prologue_layers: int = 0
    prologue_ffn: str = "mlp"
    norm: str = "rms"  # 'rms' | 'layer'
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    tie_lm_head: bool = True
    adapter: AdapterConfig = AdapterConfig()
    rram: RramConfig = DEFAULT_RRAM
    dtype: Any = jnp.bfloat16
    # encoder-decoder (seamless-m4t). Encoder input arrives as precomputed
    # frame embeddings (audio frontend stub).
    encoder_layers: int = 0
    # prefix-LM (paligemma): first ``vision_tokens`` positions are
    # precomputed patch embeddings attending bidirectionally.
    vision_tokens: int = 0
    remat: bool = True
    # Unroll all layers instead of lax.scan groups. The dry-run lowers
    # unrolled so cost_analysis counts every layer (scan bodies are counted
    # once per trip otherwise); training keeps scan for compile speed.
    unroll: bool = False

    @property
    def scan_period(self) -> int:
        return len(self.mixer_pattern)

    def layer_kinds(self) -> List[Tuple[str, str]]:
        kinds = []
        for i in range(self.n_layers):
            mixer = self.mixer_pattern[i % len(self.mixer_pattern)]
            if i < self.prologue_layers:
                ffn = self.prologue_ffn
            else:
                ffn = self.ffn_pattern[i % len(self.ffn_pattern)]
            kinds.append((mixer, ffn))
        return kinds

    def body_layout(self) -> Tuple[int, int, int]:
        """(prologue, n_groups, epilogue) layer counts."""
        body = self.n_layers - self.prologue_layers
        p = self.scan_period
        # only scan when the ffn pattern is compatible with the period
        if self.unroll or len(self.ffn_pattern) not in (1, p) or body < 2 * p:
            return (self.n_layers, 0, 0)  # fully unrolled (small models)
        n_groups = body // p
        epilogue = body % p
        return (self.prologue_layers, n_groups, epilogue)


def _norm_init(cfg: ModelConfig):
    return (
        L.init_rmsnorm(cfg.d_model)
        if cfg.norm == "rms"
        else L.init_layernorm(cfg.d_model)
    )


def _norm(x, p, cfg: ModelConfig):
    return L.rms_norm(x, p) if cfg.norm == "rms" else L.layer_norm(x, p)


def _attn_cfg(cfg: ModelConfig, kind: str, cross: bool = False):
    base = cfg.attn
    window = None
    if kind == "local":
        window = cfg.local_window
    elif kind == "swa":
        window = cfg.local_window
    return dataclasses.replace(base, window=window, is_cross=cross)


# ---------------------------------------------------------------------------
# per-layer init / forward
# ---------------------------------------------------------------------------


def init_layer(
    key: jax.Array, cfg: ModelConfig, mixer: str, ffn: str, *, cross: bool = False
) -> Tuple[Dict, Dict]:
    keys = jax.random.split(key, 4)
    base: Dict = {"norm1": _norm_init(cfg)}
    adapters: Dict = {}
    if mixer in ("attn", "local", "swa"):
        base["mixer"], adapters["mixer"] = A.init_attention(
            keys[0], _attn_cfg(cfg, mixer), cfg.adapter, cfg.dtype
        )
    elif mixer == "ssm":
        base["mixer"], adapters["mixer"] = S.init_ssm(
            keys[0], cfg.ssm, cfg.adapter, cfg.dtype
        )
    elif mixer == "rglru":
        base["mixer"], adapters["mixer"] = R.init_rglru(
            keys[0], cfg.rglru, cfg.adapter, cfg.dtype
        )
    else:
        raise ValueError(mixer)
    if cross:
        base["norm_x"] = _norm_init(cfg)
        base["xattn"], adapters["xattn"] = A.init_attention(
            keys[1], _attn_cfg(cfg, "attn", cross=True), cfg.adapter, cfg.dtype
        )
    if ffn == "mlp":
        base["norm2"] = _norm_init(cfg)
        base["ffn"], adapters["ffn"] = L.init_mlp(
            keys[2], cfg.mlp, cfg.adapter, cfg.dtype
        )
    elif ffn == "moe":
        base["norm2"] = _norm_init(cfg)
        base["ffn"], adapters["ffn"] = M.init_moe(
            keys[2], cfg.moe, cfg.adapter, cfg.dtype
        )
    return base, adapters


def block_forward(
    h: jax.Array,
    base: Dict,
    adapters: Optional[Dict],
    cfg: ModelConfig,
    mixer: str,
    ffn: str,
    *,
    positions: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
) -> jax.Array:
    a_ = adapters or {}
    x = _norm(h, base["norm1"], cfg)
    if mixer in ("attn", "local", "swa"):
        acfg = _attn_cfg(cfg, mixer)
        mix = A.attention(
            x, base["mixer"], a_.get("mixer"), acfg, cfg.adapter,
            positions=positions, mask=mask,
        )
    elif mixer == "ssm":
        mix = S.ssm_block(x, base["mixer"], a_.get("mixer"), cfg.ssm, cfg.adapter)
    elif mixer == "rglru":
        mix = R.rglru_block(x, base["mixer"], a_.get("mixer"), cfg.rglru, cfg.adapter)
    else:
        raise ValueError(mixer)
    h = h + mix
    if "xattn" in base:
        x = _norm(h, base["norm_x"], cfg)
        h = h + A.attention(
            x, base["xattn"], a_.get("xattn"),
            _attn_cfg(cfg, "attn", cross=True), cfg.adapter, kv_input=enc_out,
        )
    if ffn == "mlp":
        x = _norm(h, base["norm2"], cfg)
        h = h + L.mlp(x, base["ffn"], a_.get("ffn"), cfg.mlp, cfg.adapter)
    elif ffn == "moe":
        x = _norm(h, base["norm2"], cfg)
        h = h + M.moe_block(x, base["ffn"], a_.get("ffn"), cfg.moe, cfg.adapter)
    return h


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict:
    """Returns {"base": ..., "adapters": ...} with mirrored structure."""
    keys = jax.random.split(key, cfg.n_layers + cfg.encoder_layers + 3)
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period
    base: Dict = {}
    adapters: Dict = {}
    base["embed"] = L.init_embedding(keys[0], cfg.vocab, cfg.d_model, cfg.dtype)
    base["final_norm"] = _norm_init(cfg)
    if not cfg.tie_lm_head:
        base["lm_head"], adapters["lm_head"] = L.init_linear(
            keys[1], cfg.d_model, cfg.vocab, cfg.adapter, dtype=cfg.dtype
        )

    is_dec_cross = cfg.encoder_layers > 0

    def make(i):
        mixer, ffn = kinds[i]
        return init_layer(keys[3 + i], cfg, mixer, ffn, cross=is_dec_cross)

    base["prologue"], adapters["prologue"] = [], []
    for i in range(pro):
        b, a_ = make(i)
        base["prologue"].append(b)
        adapters["prologue"].append(a_)
    if n_groups:
        group_bases, group_ads = [], []
        for g in range(n_groups):
            bs, as_ = zip(*[make(pro + g * p + j) for j in range(p)])
            group_bases.append(list(bs))
            group_ads.append(list(as_))
        base["body"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *group_bases
        )
        adapters["body"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *group_ads
        )
    base["epilogue"], adapters["epilogue"] = [], []
    for i in range(cfg.n_layers - epi, cfg.n_layers):
        b, a_ = make(i)
        base["epilogue"].append(b)
        adapters["epilogue"].append(a_)

    if cfg.encoder_layers:
        enc_b, enc_a = [], []
        for e in range(cfg.encoder_layers):
            b, a_ = init_layer(
                keys[3 + cfg.n_layers + e], cfg, "attn", "mlp", cross=False
            )
            enc_b.append(b)
            enc_a.append(a_)
        if cfg.unroll:
            base["encoder"] = enc_b
            adapters["encoder"] = enc_a
        else:
            base["encoder"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *enc_b
            )
            adapters["encoder"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *enc_a
            )
        base["enc_norm"] = _norm_init(cfg)
    return {"base": base, "adapters": adapters}


# ---------------------------------------------------------------------------
# full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _prefix_mask(s: int, prefix: int) -> jax.Array:
    """Prefix-LM mask: bidirectional over [0, prefix), causal after."""
    q = jnp.arange(s)[:, None]
    k = jnp.arange(s)[None, :]
    return (k <= q) | (k < prefix)


def encode(base, adapters, enc_embeds, cfg: ModelConfig) -> jax.Array:
    """Bidirectional encoder over precomputed frontend embeddings."""
    s = enc_embeds.shape[1]
    mask = jnp.ones((s, s), bool)
    positions = jnp.arange(s)[None]

    if cfg.unroll:
        h = enc_embeds
        enc_a = adapters.get("encoder") or [{}] * cfg.encoder_layers
        for b, a_ in zip(base["encoder"], enc_a):
            h = block_forward(h, b, a_, cfg, "attn", "mlp", mask=mask,
                              positions=positions)
        return _norm(h, base["enc_norm"], cfg)

    def enc_block(h, xs):
        b, a_ = xs
        h = block_forward(
            h, b, a_, cfg, "attn", "mlp", mask=mask, positions=positions,
        )
        return h, None

    f = _maybe_remat(enc_block, cfg)
    h, _ = jax.lax.scan(f, enc_embeds, (base["encoder"], adapters.get("encoder")))
    return _norm(h, base["enc_norm"], cfg)


def forward(
    params: Dict,
    batch: Dict,
    cfg: ModelConfig,
    *,
    use_adapters: bool = True,
) -> jax.Array:
    """Full-sequence forward -> logits. ``batch`` keys:
    tokens (B,S) int32; optional enc_embeds (B,S_src,d) [enc-dec];
    optional patch_embeds (B,P,d) [vlm]."""
    base = params["base"]
    adapters = params.get("adapters") if use_adapters else None
    if not adapters:
        # container skeleton with empty leaf-dicts (teacher/pure-RRAM path);
        # base mirrors the adapter tree's containers, so derive from it
        adapters = _empty_adapters(base)
    h = L.embed(batch["tokens"], base["embed"], scale_by_sqrt_dim=cfg.embed_scale)
    mask = None
    prefix = 0
    if cfg.vision_tokens and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(h.dtype), h], axis=1)
        prefix = batch["patch_embeds"].shape[1]
        mask = _prefix_mask(h.shape[1], prefix)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(base, adapters, batch["enc_embeds"].astype(h.dtype), cfg)
    s = h.shape[1]
    positions = jnp.arange(s)[None]
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period

    def run_block(h, b, a_, i_kind, local_mask):
        mixer, ffn = i_kind
        return block_forward(
            h, b, a_, cfg, mixer, ffn,
            positions=positions, mask=local_mask, enc_out=enc_out,
        )

    idx = 0
    for i in range(pro):
        h = run_block(h, base["prologue"][i], adapters["prologue"][i], kinds[i], mask)
        idx += 1
    if n_groups:
        body_kinds = [kinds[pro + j] for j in range(p)]

        def group(h, xs):
            bs, as_ = xs
            for j in range(p):
                h = run_block(h, bs[j], as_[j], body_kinds[j], mask)
            return h, None

        f = _maybe_remat(group, cfg)
        h, _ = jax.lax.scan(f, h, (base["body"], adapters.get("body")))
        idx += n_groups * p
    for j, i in enumerate(range(cfg.n_layers - epi, cfg.n_layers)):
        h = run_block(h, base["epilogue"][j], adapters["epilogue"][j], kinds[i], mask)
    h = _norm(h, base["final_norm"], cfg)
    logits = _lm_head(h, base, adapters, cfg)
    if prefix:
        logits = logits[:, prefix:]
    return logits


def _lm_head(h, base, adapters, cfg: ModelConfig):
    if cfg.tie_lm_head:
        w = base["embed"]["embedding"]
        return h @ w.astype(h.dtype).T
    return L.linear(h, base["lm_head"], adapters.get("lm_head"), cfg.adapter)


def _empty_adapters(tree):
    if isinstance(tree, dict):
        return {k: _empty_adapters(v) for k, v in tree.items() if isinstance(v, (dict, list))}
    if isinstance(tree, list):
        return [_empty_adapters(v) for v in tree]
    return {}


# ---------------------------------------------------------------------------
# feature-based layer-wise calibration loss (paper Algorithm 1 + 2)
# ---------------------------------------------------------------------------
#
# The student block receives the *teacher's* block input (h is always the
# teacher activation), so gradients w.r.t. a block's DoRA parameters never
# cross block boundaries — "layer-wise, no backpropagation" (§III-B) as a
# single jittable step. Summing per-layer MSEs yields exactly the per-layer
# gradients of Algorithm 1's inner loop.


def feature_calibration_loss(
    teacher_base: Dict,
    student_base: Dict,
    adapters: Dict,
    batch: Dict,
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict]:
    h = L.embed(batch["tokens"], teacher_base["embed"],
                scale_by_sqrt_dim=cfg.embed_scale)
    mask = None
    if cfg.vision_tokens and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(h.dtype), h], axis=1)
        mask = _prefix_mask(h.shape[1], batch["patch_embeds"].shape[1])
    s = h.shape[1]
    positions = jnp.arange(s)[None]
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period
    loss = jnp.zeros((), jnp.float32)
    n_terms = 0

    enc_out = None
    if cfg.encoder_layers:
        src = batch["enc_embeds"].astype(h.dtype)
        s_src = src.shape[1]
        enc_mask = jnp.ones((s_src, s_src), bool)
        enc_pos = jnp.arange(s_src)[None]

        def enc_pair_one(he, tb, sb, a_):
            t_out = block_forward(he, tb, {}, cfg, "attn", "mlp",
                                  positions=enc_pos, mask=enc_mask)
            s_out = block_forward(he, sb, a_, cfg, "attn", "mlp",
                                  positions=enc_pos, mask=enc_mask)
            return t_out, _mse(t_out, s_out)

        if cfg.unroll:
            h_enc = src
            for tb, sb, a_ in zip(teacher_base["encoder"],
                                  student_base["encoder"],
                                  adapters.get("encoder")):
                h_enc, l_ = enc_pair_one(h_enc, tb, sb, a_)
                loss = loss + l_
        else:
            def enc_pair(carry, xs):
                he, acc = carry
                t_out, l_ = enc_pair_one(he, *xs)
                return (t_out, acc + l_), None

            f = _maybe_remat(enc_pair, cfg)
            (h_enc, loss), _ = jax.lax.scan(
                f, (src, loss),
                (teacher_base["encoder"], student_base["encoder"],
                 adapters.get("encoder")),
            )
        enc_out = _norm(h_enc, teacher_base["enc_norm"], cfg)
        n_terms += cfg.encoder_layers

    def pair(h, tb, sb, a_, kind):
        mixer, ffn = kind
        t_out = block_forward(h, tb, {}, cfg, mixer, ffn,
                              positions=positions, mask=mask, enc_out=enc_out)
        s_out = block_forward(h, sb, a_, cfg, mixer, ffn,
                              positions=positions, mask=mask, enc_out=enc_out)
        return t_out, _mse(t_out, s_out)

    for i in range(pro):
        h, l_ = pair(h, teacher_base["prologue"][i], student_base["prologue"][i],
                     adapters["prologue"][i], kinds[i])
        loss = loss + l_
        n_terms += 1
    if n_groups:
        body_kinds = [kinds[pro + j] for j in range(p)]

        def group(carry, xs):
            h, acc = carry
            tbs, sbs, as_ = xs
            for j in range(p):
                h, l_ = pair(h, tbs[j], sbs[j], as_[j], body_kinds[j])
                acc = acc + l_
            return (h, acc), None

        f = _maybe_remat(group, cfg)
        (h, loss), _ = jax.lax.scan(
            f, (h, loss),
            (teacher_base["body"], student_base["body"], adapters.get("body")),
        )
        n_terms += n_groups * p
    for j, i in enumerate(range(cfg.n_layers - epi, cfg.n_layers)):
        h, l_ = pair(h, teacher_base["epilogue"][j], student_base["epilogue"][j],
                     adapters["epilogue"][j], kinds[i])
        loss = loss + l_
        n_terms += 1

    # LM head (untied heads live in RRAM -> align logits too)
    if not cfg.tie_lm_head:
        hn = _norm(h, teacher_base["final_norm"], cfg)
        t_logits = L.linear(hn, teacher_base["lm_head"], {}, cfg.adapter)
        s_logits = L.linear(
            hn, student_base["lm_head"], adapters.get("lm_head"), cfg.adapter
        )
        loss = loss + _mse(t_logits, s_logits)
        n_terms += 1
    loss = loss / n_terms
    return loss, {"feature_mse": loss}


def _mse(a: jax.Array, b: jax.Array) -> jax.Array:
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.mean(d * d)


# ---------------------------------------------------------------------------
# decode path (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int = 0) -> Dict:
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period

    def layer_cache(mixer):
        if mixer in ("attn", "local", "swa"):
            c = A.init_kv_cache(batch, max_len, _attn_cfg(cfg, mixer), cfg.dtype)
        elif mixer == "ssm":
            c = S.init_ssm_cache(batch, cfg.ssm)
        elif mixer == "rglru":
            c = R.init_rglru_cache(batch, cfg.rglru)
        else:
            raise ValueError(mixer)
        if cfg.encoder_layers:
            # Per-layer cross-attention lines: encoder K/V computed once
            # at admission (encode_into_cache / prefill) and reused by
            # every decode tick, masked per slot by cache["enc_len"].
            c = dict(c)
            c.update(A.init_cross_cache(
                batch, max(src_len, 1), _attn_cfg(cfg, "attn", cross=True),
                cfg.dtype,
            ))
        return c

    cache: Dict = {"prologue": [layer_cache(kinds[i][0]) for i in range(pro)]}
    if n_groups:
        groups = []
        for g in range(n_groups):
            groups.append([layer_cache(kinds[pro + g * p + j][0]) for j in range(p)])
        cache["body"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)
    cache["epilogue"] = [
        layer_cache(kinds[i][0]) for i in range(cfg.n_layers - epi, cfg.n_layers)
    ]
    if cfg.encoder_layers:
        cache["enc_len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def write_cache_slot(cache: Dict, one: Dict, slot: int) -> Dict:
    """Copy a single-request (batch=1) cache into row ``slot`` of a
    batched cache — slot admission in the continuous-batching engine.
    Buffer extents must match (build ``one`` with the engine's
    ``max_len``). Handles the stacked scan-body leaves (batch is axis 1
    behind the group axis) and the unstacked prologue/epilogue lists."""
    new: Dict = {}
    for k, v in cache.items():
        if k == "body":
            new[k] = jax.tree_util.tree_map(
                lambda big, o: big.at[:, slot].set(o[:, 0]), v, one[k]
            )
        else:
            new[k] = jax.tree_util.tree_map(
                lambda big, o: big.at[slot].set(o[0]), v, one[k]
            )
    return new


def encode_into_cache(params: Dict, cache: Dict, enc_embeds, cfg: ModelConfig) -> Dict:
    """Run the encoder once and scatter each decoder layer's
    cross-attention K/V ("xk"/"xv") plus the per-slot valid source length
    ("enc_len") into a decode cache. The cache keeps a padded source
    extent; positions past ``enc_len`` are masked to exact softmax zero,
    so ragged encoder inputs across slots stay bitwise the exact-length
    computation."""
    base, adapters = params["base"], params["adapters"]
    if not adapters:
        adapters = _empty_adapters(base)
    enc_out = encode(base, adapters, enc_embeds.astype(cfg.dtype), cfg)
    s_src = enc_out.shape[1]
    xcfg = _attn_cfg(cfg, "attn", cross=True)

    def fill(cache_l, b, a_):
        k, v = A.cross_kv(enc_out, b["xattn"], (a_ or {}).get("xattn"), xcfg,
                          cfg.adapter)
        new = dict(cache_l)
        new["xk"] = cache_l["xk"].at[:, :s_src].set(k.astype(cache_l["xk"].dtype))
        new["xv"] = cache_l["xv"].at[:, :s_src].set(v.astype(cache_l["xv"].dtype))
        return new

    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period
    new_cache = dict(cache)
    new_cache["prologue"] = [
        fill(cache["prologue"][i], base["prologue"][i], adapters["prologue"][i])
        for i in range(pro)
    ]
    if n_groups:
        def group(_, xs):
            cs, bs, as_ = xs
            return None, [fill(cs[j], bs[j], as_[j]) for j in range(p)]

        _, body = jax.lax.scan(
            group, None, (cache["body"], base["body"], adapters.get("body"))
        )
        new_cache["body"] = body
    new_cache["epilogue"] = [
        fill(cache["epilogue"][j], base["epilogue"][j], adapters["epilogue"][j])
        for j in range(epi)
    ]
    new_cache["enc_len"] = jnp.full_like(cache["enc_len"], s_src)
    return new_cache


def _prefill_block(
    h, b, a_, cfg: ModelConfig, mixer: str, ffn: str, *,
    positions, max_len: int, enc_out=None, mask=None,
):
    """``block_forward`` that also emits the layer's decode cache: K/V
    (post-rope) scattered at positions [0, s), MLA latents, or the
    recurrent state + conv window after the last position. Encoder-decoder
    layers additionally emit their cross-attention K/V lines."""
    a_ = a_ or {}
    x = _norm(h, b["norm1"], cfg)
    if mixer in ("attn", "local", "swa"):
        acfg = _attn_cfg(cfg, mixer)
        mix, kv = A.attention(
            x, b["mixer"], a_.get("mixer"), acfg, cfg.adapter,
            positions=positions, mask=mask, return_kv=True,
        )
        layer_cache = A.prefill_kv_cache(
            kv, h.shape[0], max_len, acfg, cfg.dtype
        )
    elif mixer == "ssm":
        mix, layer_cache = S.ssm_block(
            x, b["mixer"], a_.get("mixer"), cfg.ssm, cfg.adapter,
            return_state=True,
        )
    elif mixer == "rglru":
        mix, layer_cache = R.rglru_block(
            x, b["mixer"], a_.get("mixer"), cfg.rglru, cfg.adapter,
            return_state=True,
        )
    else:
        raise ValueError(mixer)
    h = h + mix
    if "xattn" in b and enc_out is not None:
        x = _norm(h, b["norm_x"], cfg)
        xa, xkv = A.attention(
            x, b["xattn"], a_.get("xattn"),
            _attn_cfg(cfg, "attn", cross=True), cfg.adapter, kv_input=enc_out,
            return_kv=True,
        )
        h = h + xa
        layer_cache = dict(layer_cache)
        layer_cache["xk"] = xkv["k"].astype(cfg.dtype)
        layer_cache["xv"] = xkv["v"].astype(cfg.dtype)
    if ffn in ("mlp", "moe"):
        x = _norm(h, b["norm2"], cfg)
        if ffn == "mlp":
            h = h + L.mlp(x, b["ffn"], a_.get("ffn"), cfg.mlp, cfg.adapter)
        else:
            h = h + M.moe_block(x, b["ffn"], a_.get("ffn"), cfg.moe, cfg.adapter)
    return h, layer_cache


def prefill(
    params: Dict,
    tokens: jax.Array,  # (B, S) int32
    cfg: ModelConfig,
    max_len: int,
    enc_embeds: Optional[jax.Array] = None,
    patch_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Fused full-sequence prefill: ONE forward pass over the whole
    prompt that returns the last-position logits ``(B, 1, vocab)`` and a
    decode cache ready for ``decode_step`` at ``pos = S`` — K/V (and MLA
    latents / recurrent states) are computed batched over the sequence
    and scattered into each buffer, instead of S per-token decode steps
    (the old serving loop). Parity: tests/test_engine.py.

    ``patch_embeds`` (B, P, d) prepends a bidirectional prefix-LM vision
    prefix (paligemma): positions 0..P-1 are patches, the decode clock
    then starts at ``P + S``. Encoder-decoder configs emit per-layer
    cross-attention K/V lines plus ``enc_len``."""
    base, adapters = params["base"], params["adapters"]
    if not adapters:
        adapters = _empty_adapters(base)
    b, s = tokens.shape
    h = L.embed(tokens, base["embed"], scale_by_sqrt_dim=cfg.embed_scale)
    mask = None
    if patch_embeds is not None:
        h = jnp.concatenate([patch_embeds.astype(h.dtype), h], axis=1)
        mask = _prefix_mask(h.shape[1], patch_embeds.shape[1])
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(base, adapters, enc_embeds.astype(h.dtype), cfg)
    s_tot = h.shape[1]
    positions = jnp.arange(s_tot)[None]
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period
    cache: Dict = {"prologue": [], "epilogue": []}
    if enc_out is not None:
        cache["enc_len"] = jnp.full((b,), enc_out.shape[1], jnp.int32)

    def run(h, b_, a_, kind):
        return _prefill_block(
            h, b_, a_, cfg, *kind, positions=positions, max_len=max_len,
            enc_out=enc_out, mask=mask,
        )

    for i in range(pro):
        h, c = run(h, base["prologue"][i], adapters["prologue"][i], kinds[i])
        cache["prologue"].append(c)
    if n_groups:
        body_kinds = [kinds[pro + j] for j in range(p)]

        def group(h, xs):
            bs, as_ = xs
            cs = []
            for j in range(p):
                h, c = run(h, bs[j], as_[j], body_kinds[j])
                cs.append(c)
            return h, cs

        h, body_cache = jax.lax.scan(
            group, h, (base["body"], adapters.get("body"))
        )
        cache["body"] = body_cache
    for j, i in enumerate(range(cfg.n_layers - epi, cfg.n_layers)):
        h, c = run(h, base["epilogue"][j], adapters["epilogue"][j], kinds[i])
        cache["epilogue"].append(c)
    h = _norm(h, base["final_norm"], cfg)
    logits = _lm_head(h, base, adapters, cfg)
    return logits[:, -1:], cache


def _decode_block(
    h, cache_l, pos, b, a_, cfg: ModelConfig, mixer: str, ffn: str,
    enc_len=None,
):
    a_ = a_ or {}
    x = _norm(h, b["norm1"], cfg)
    if mixer in ("attn", "local", "swa"):
        acfg = _attn_cfg(cfg, mixer)
        mix, new_cache = A.decode_attention(
            x, cache_l, pos, b["mixer"], a_.get("mixer"), acfg, cfg.adapter
        )
    elif mixer == "ssm":
        mix, new_cache = S.ssm_decode(
            x, cache_l, b["mixer"], a_.get("mixer"), cfg.ssm, cfg.adapter
        )
    elif mixer == "rglru":
        mix, new_cache = R.rglru_decode(
            x, cache_l, b["mixer"], a_.get("mixer"), cfg.rglru, cfg.adapter
        )
    else:
        raise ValueError(mixer)
    h = h + mix
    if "xattn" in b and enc_len is not None:
        x = _norm(h, b["norm_x"], cfg)
        h = h + A.cross_attention_cached(
            x, cache_l, enc_len, b["xattn"], a_.get("xattn"),
            _attn_cfg(cfg, "attn", cross=True), cfg.adapter,
        )
        # cross K/V lines are frozen after admission — carry them forward
        new_cache = dict(new_cache)
        new_cache["xk"] = cache_l["xk"]
        new_cache["xv"] = cache_l["xv"]
    if ffn in ("mlp", "moe"):
        x = _norm(h, b["norm2"], cfg)
        if ffn == "mlp":
            h = h + L.mlp(x, b["ffn"], a_.get("ffn"), cfg.mlp, cfg.adapter)
        else:
            h = h + M.moe_block(x, b["ffn"], a_.get("ffn"), cfg.moe, cfg.adapter)
    return h, new_cache


def decode_step(
    params: Dict,
    cache: Dict,
    tokens: jax.Array,  # (B, 1) int32
    pos: jax.Array,  # (B,) int32 per-slot clocks; scalar broadcasts
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict]:
    """One batched decode tick. ``pos[b]`` is row ``b``'s sequence clock,
    so a continuous batch can carry requests at different offsets (ragged
    prompts, staggered admission); attention caches write and mask per
    slot. SSM/RG-LRU state is per-row already and needs no clock."""
    base, adapters = params["base"], params["adapters"]
    pos = A._as_pos_vector(pos, tokens.shape[0])
    h = L.embed(
        tokens, base["embed"], scale_by_sqrt_dim=cfg.embed_scale, one_hot=True
    )
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period
    enc_len = cache.get("enc_len")
    new_cache: Dict = {"prologue": [], "epilogue": []}
    if enc_len is not None:
        new_cache["enc_len"] = enc_len
    for i in range(pro):
        h, c = _decode_block(
            h, cache["prologue"][i], pos, base["prologue"][i],
            adapters["prologue"][i], cfg, *kinds[i], enc_len=enc_len,
        )
        new_cache["prologue"].append(c)
    if n_groups:
        body_kinds = [kinds[pro + j] for j in range(p)]

        def group(h, xs):
            bs, as_, cs = xs
            new_cs = []
            for j in range(p):
                h, c = _decode_block(
                    h, cs[j], pos, bs[j], as_[j], cfg, *body_kinds[j],
                    enc_len=enc_len,
                )
                new_cs.append(c)
            return h, new_cs

        h, body_cache = jax.lax.scan(
            group, h, (base["body"], adapters.get("body"), cache["body"])
        )
        new_cache["body"] = body_cache
    for j, i in enumerate(range(cfg.n_layers - epi, cfg.n_layers)):
        h, c = _decode_block(
            h, cache["epilogue"][j], pos, base["epilogue"][j],
            adapters["epilogue"][j], cfg, *kinds[i], enc_len=enc_len,
        )
        new_cache["epilogue"].append(c)
    h = _norm(h, base["final_norm"], cfg)
    logits = _lm_head(h, base, adapters, cfg)
    return logits, new_cache


# ---------------------------------------------------------------------------
# chunked prefill (admission interleaved with decode ticks)
# ---------------------------------------------------------------------------
#
# ``prefill_chunk`` advances a live decode cache by one fixed-size prompt
# chunk — the engine splits long prompts into bucketed chunks so
# admission never stalls in-flight slots and the jit cache stays bounded
# (a handful of chunk buckets instead of one program per prompt length).
# Only attention mixers chunk: SSM/RG-LRU recurrences are computed with
# ``associative_scan`` whose regrouping is length-dependent, so those
# configs keep the fused exact-length prefill.


def _chunk_block(
    h, cache_l, pos0, n_valid, b, a_, cfg: ModelConfig, mixer: str, ffn: str,
    *, enc_len=None, max_len: int, prefix: int = 0,
):
    a_ = a_ or {}
    x = _norm(h, b["norm1"], cfg)
    if mixer not in ("attn", "local", "swa"):
        raise ValueError(
            f"chunked prefill supports attention mixers only, got {mixer!r}"
        )
    acfg = _attn_cfg(cfg, mixer)
    mix, new_kv = A.chunk_attention(
        x, cache_l, pos0, n_valid, b["mixer"], a_.get("mixer"), acfg,
        cfg.adapter, max_len=max_len, prefix=prefix,
    )
    new_cache = {**cache_l, **new_kv}
    h = h + mix
    if "xattn" in b and enc_len is not None:
        x = _norm(h, b["norm_x"], cfg)
        h = h + A.cross_attention_cached(
            x, cache_l, enc_len, b["xattn"], a_.get("xattn"),
            _attn_cfg(cfg, "attn", cross=True), cfg.adapter,
        )
    if ffn in ("mlp", "moe"):
        x = _norm(h, b["norm2"], cfg)
        if ffn == "mlp":
            h = h + L.mlp(x, b["ffn"], a_.get("ffn"), cfg.mlp, cfg.adapter)
        else:
            h = h + M.moe_block(x, b["ffn"], a_.get("ffn"), cfg.moe, cfg.adapter)
    return h, new_cache


def _chunk_stack(
    params, h, cache, pos0, n_valid, cfg: ModelConfig, max_len: int,
    prefix: int,
):
    """Walk the layer stack applying ``_chunk_block``; returns (h, cache)
    with the final norm applied to ``h``."""
    base, adapters = params["base"], params["adapters"]
    if not adapters:
        adapters = _empty_adapters(base)
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period
    enc_len = cache.get("enc_len")
    new_cache: Dict = {"prologue": [], "epilogue": []}
    if enc_len is not None:
        new_cache["enc_len"] = enc_len

    def run(h, cache_l, b_, a_, kind):
        return _chunk_block(
            h, cache_l, pos0, n_valid, b_, a_, cfg, *kind,
            enc_len=enc_len, max_len=max_len, prefix=prefix,
        )

    for i in range(pro):
        h, c = run(h, cache["prologue"][i], base["prologue"][i],
                   adapters["prologue"][i], kinds[i])
        new_cache["prologue"].append(c)
    if n_groups:
        body_kinds = [kinds[pro + j] for j in range(p)]

        def group(h, xs):
            bs, as_, cs = xs
            new_cs = []
            for j in range(p):
                h, c = run(h, cs[j], bs[j], as_[j], body_kinds[j])
                new_cs.append(c)
            return h, new_cs

        h, body_cache = jax.lax.scan(
            group, h, (base["body"], adapters.get("body"), cache["body"])
        )
        new_cache["body"] = body_cache
    for j, i in enumerate(range(cfg.n_layers - epi, cfg.n_layers)):
        h, c = run(h, cache["epilogue"][j], base["epilogue"][j],
                   adapters["epilogue"][j], kinds[i])
        new_cache["epilogue"].append(c)
    h = _norm(h, base["final_norm"], cfg)
    return h, new_cache


def prefill_chunk(
    params: Dict,
    tokens: jax.Array,  # (B, C) int32 — bucketed chunk, zero-padded tail
    cache: Dict,
    pos0: jax.Array,  # (B,) absolute position of tokens[:, 0]
    n_valid: jax.Array,  # (B,) real tokens in the chunk
    cfg: ModelConfig,
    max_len: int,
    prefix: int = 0,  # static vision-prefix extent (0 for text-only)
) -> Tuple[jax.Array, Dict]:
    """Advance a decode cache by one prompt chunk. Returns the logits at
    the chunk's last *valid* position ``(B, 1, vocab)`` and the updated
    cache — bitwise the fused ``prefill`` of the same tokens (pinned in
    tests/test_engine.py)."""
    base = params["base"]
    b, _ = tokens.shape
    pos0 = A._as_pos_vector(pos0, b)
    n_valid = A._as_pos_vector(n_valid, b)
    h = L.embed(tokens, base["embed"], scale_by_sqrt_dim=cfg.embed_scale)
    h, new_cache = _chunk_stack(
        params, h, cache, pos0, n_valid, cfg, max_len, prefix
    )
    rows = jnp.arange(b)
    h_last = h[rows, n_valid - 1][:, None]  # (B, 1, d)
    adapters = params["adapters"] or _empty_adapters(base)
    logits = _lm_head(h_last, base, adapters, cfg)
    return logits, new_cache


def prefill_vision(
    params: Dict,
    patch_embeds: jax.Array,  # (B, P, d)
    cache: Dict,
    cfg: ModelConfig,
    max_len: int,
) -> Dict:
    """Admit a vision prefix into a decode cache: the P patch positions
    attend bidirectionally among themselves (prefix-LM), text chunks and
    decode ticks then start at ``pos0 = P``. One static shape per config
    (P = cfg.vision_tokens), so this compiles exactly once."""
    b, p_, _ = patch_embeds.shape
    h = patch_embeds.astype(cfg.dtype)
    pos0 = jnp.zeros((b,), jnp.int32)
    n_valid = jnp.full((b,), p_, jnp.int32)
    _, new_cache = _chunk_stack(params, h, cache, pos0, n_valid, cfg,
                                max_len, prefix=p_)
    return new_cache


# ---------------------------------------------------------------------------
# parameter accounting (roofline MODEL_FLOPS, paper Eq. 7 at model scale)
# ---------------------------------------------------------------------------


def count_params(params: Dict) -> Tuple[int, int]:
    """(base_params, adapter_params). A codes-resident ``CrossbarWeight``
    counts its LOGICAL weight count once (g_pos/g_neg are two physical
    devices per weight, not two weights)."""
    from repro.core.rram import CrossbarWeight

    def size(tree):
        total = 0
        for x in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda n: isinstance(n, CrossbarWeight)
        ):
            if isinstance(x, CrossbarWeight):
                total += x.g_pos.size
            elif hasattr(x, "size"):
                total += x.size
        return total

    return size(params["base"]), size(params["adapters"])


def active_param_fraction(cfg: ModelConfig, params: Dict) -> float:
    """Fraction of parameters that are active per token (1.0 for dense;
    (shared + top_k/n_experts routed) for MoE FFN weights)."""
    if cfg.moe is None:
        return 1.0
    base, _ = count_params(params)
    # routed expert weights
    routed = _tree_key_size(params["base"], "gate_w") + _tree_key_size(
        params["base"], "up_w"
    ) + _tree_key_size(params["base"], "down_w")
    active = base - routed * (1 - cfg.moe.top_k / cfg.moe.n_experts)
    return active / base


def _tree_key_size(tree, key) -> int:
    from repro.core.rram import CrossbarWeight

    total = 0
    if isinstance(tree, dict):
        for k, v in tree.items():
            if k == key:
                if isinstance(v, CrossbarWeight):
                    total += v.g_pos.size
                else:
                    total += sum(
                        x.size for x in jax.tree_util.tree_leaves(v)
                    )
            else:
                total += _tree_key_size(v, key)
    elif isinstance(tree, list):
        for v in tree:
            total += _tree_key_size(v, key)
    return total
