"""Shared layer primitives. Every matmul in the model zoo goes through
``RimcLinear`` — the paper's unit of calibration: a frozen (possibly
drifted) base weight that lives "in RRAM", plus an optional DoRA/LoRA
side-car that lives "in SRAM" (trainable).

Parameter convention
--------------------
``init_*`` functions return ``(base, adapters)`` pytrees with *mirrored*
structure. ``base`` holds frozen weights; ``adapters`` holds the trainable
DoRA parameters (possibly ``{}`` for layers without adapters, e.g. norms).
The two trees are kept separate at the top level so the optimizer and the
drift-programming pass each see exactly one tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dora
from repro.core.dora import AdapterConfig
from repro.core.rram import CrossbarWeight
from repro.substrate.prepared import PreparedCrossbar, ShardedPrepared

Pytree = Any


# ---------------------------------------------------------------------------
# RimcLinear
# ---------------------------------------------------------------------------


def init_linear(
    key: jax.Array,
    d_in: int,
    d_out: int,
    acfg: AdapterConfig,
    *,
    dtype=jnp.bfloat16,
    scale: Optional[float] = None,
) -> Tuple[Dict, Dict]:
    kw, ka = jax.random.split(key)
    if scale is None:
        scale = d_in ** -0.5
    w = (jax.random.normal(kw, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    adapter = dora.init_adapter(ka, d_in, d_out, acfg, w_base=w)
    return {"w": w}, adapter


def linear(
    x: jax.Array,
    base: Dict,
    adapter: Optional[Dict],
    acfg: AdapterConfig,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """Apply a RimcLinear. ``adapter=None`` or ``{}`` -> plain base matmul
    (teacher path / pure-RRAM student).

    This is the single choke point every matmul in the model zoo goes
    through: when the base leaf is a resident ``CrossbarWeight``
    (``program_model(mode="codes")``), the call dispatches to the
    substrate's execution backends (codes / codes_adc / dequant —
    ``repro/substrate``); float leaves keep the plain jnp path.
    """
    w = base["w"]
    if isinstance(w, (CrossbarWeight, PreparedCrossbar, ShardedPrepared)):
        from repro.substrate import crossbar_linear

        # PreparedCrossbar (serve-time padded/fused codes with the
        # adapter baked in — substrate/prepared.py) ignores ``adapter``;
        # ShardedPrepared is its tensor-parallel form inside shard_map.
        return crossbar_linear(x, w, adapter, acfg, backend=backend)
    if adapter:
        return dora.adapted_forward(x, w, adapter, acfg)
    return x @ w.astype(x.dtype)


def init_kernel_linear(*args, **kwargs):  # alias used by kernels/ops tests
    return init_linear(*args, **kwargs)


# ---------------------------------------------------------------------------
# Norms (digital peripherals — never in RRAM, never trainable during
# calibration: the paper's "no BN update" analogue)
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(x: jax.Array, p: Dict, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(x: jax.Array, p: Dict, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding (digital: gather, not an MVM — crossbars can't index)
# ---------------------------------------------------------------------------


def init_embedding(
    key: jax.Array, vocab: int, d: int, dtype=jnp.bfloat16
) -> Dict:
    w = jax.random.normal(key, (vocab, d), jnp.float32).astype(dtype)
    return {"embedding": w}


def embed(
    tokens: jax.Array, p: Dict, *, scale_by_sqrt_dim: bool = False,
    one_hot: bool = False,
):
    """Token embedding lookup.

    ``one_hot=True`` computes the lookup as a one-hot matmul: with the
    table vocab-sharded over the model axis, XLA then emits a tiny
    (tokens, d) psum instead of all-gathering the whole table (2+ GB for
    256k-vocab archs). Used by the decode path where tokens-per-step is
    O(batch) (§Perf H-5); the gather path stays for training (one-hot
    matmul FLOPs scale with vocab x tokens).
    """
    w = p["embedding"]
    if one_hot:
        oh = jax.nn.one_hot(tokens, w.shape[0], dtype=w.dtype)
        y = oh @ w
    else:
        y = jnp.take(w, tokens, axis=0)
    if scale_by_sqrt_dim:
        y = y * (w.shape[-1] ** 0.5)
    return y


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (.., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated) — two/three RimcLinears
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_model: int
    d_ff: int
    gated: bool = True  # SwiGLU-style (llama/gemma/qwen); False -> GeLU MLP
    activation: str = "silu"  # 'silu' | 'gelu' | 'gelu_tanh' | 'relu'


def _act(x: jax.Array, name: str) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=False)
    if name == "gelu_tanh":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name}")


def init_mlp(
    key: jax.Array, cfg: MlpConfig, acfg: AdapterConfig, dtype=jnp.bfloat16
) -> Tuple[Dict, Dict]:
    keys = jax.random.split(key, 3)
    base: Dict = {}
    adapters: Dict = {}
    if cfg.gated:
        base["gate"], adapters["gate"] = init_linear(
            keys[0], cfg.d_model, cfg.d_ff, acfg, dtype=dtype
        )
    base["up"], adapters["up"] = init_linear(
        keys[1], cfg.d_model, cfg.d_ff, acfg, dtype=dtype
    )
    base["down"], adapters["down"] = init_linear(
        keys[2], cfg.d_ff, cfg.d_model, acfg, dtype=dtype
    )
    return base, adapters


def mlp(
    x: jax.Array,
    base: Dict,
    adapters: Optional[Dict],
    cfg: MlpConfig,
    acfg: AdapterConfig,
) -> jax.Array:
    a = adapters or {}
    if "_gate_up" in base:
        # serve-time fused leaf (substrate/prepared.py): gate and up share
        # the input, so one launch over concatenated N replaces two
        gu = linear(x, base["_gate_up"], None, acfg)
        h = _act(gu[..., : cfg.d_ff], cfg.activation) * gu[..., cfg.d_ff :]
    elif cfg.gated:
        up = linear(x, base["up"], a.get("up"), acfg)
        gate = linear(x, base["gate"], a.get("gate"), acfg)
        h = _act(gate, cfg.activation) * up
    else:
        h = _act(linear(x, base["up"], a.get("up"), acfg), cfg.activation)
    return linear(h, base["down"], a.get("down"), acfg)
