"""Attention variants over RimcLinear projections.

Covers the assigned-architecture needs:
  * MHA / GQA / MQA (``kv_heads <= heads``)            — all dense archs
  * optional qk-norm (qwen3)
  * sliding-window masks (mixtral SWA, gemma3 local layers,
    recurrentgemma local layers)
  * cross-attention (seamless-m4t encoder-decoder)
  * MLA — multi-head latent attention with low-rank KV compression and
    decoupled RoPE (deepseek-v2-lite)
  * single-token decode against a KV cache (``decode_*`` / ``long_*``
    shapes); sliding-window layers keep a rolling window cache.

All projections are RimcLinear (frozen drifted base + DoRA side-car) — the
paper's technique applies uniformly. Every projection goes through
``layers.linear``, so a codes-resident deployment
(``program_model(mode="codes")``) runs q/k/v/o, the MLA latent
projections, and cross-attention on the substrate's execution backends
(repro/substrate) with no changes here — README.md ARCHITECTURE.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dora import AdapterConfig
from repro.models import layers as L

NEG_INF = -2.3819763e38  # same constant gemma uses; safe in bf16 softmax


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False  # qwen3-style per-head RMS norm on q and k
    window: Optional[int] = None  # sliding window size; None = global
    is_cross: bool = False  # cross-attention (kv from encoder output)
    softmax_scale: Optional[float] = None
    # MLA (deepseek-v2): low-rank KV joint compression + decoupled rope.
    mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def scale(self) -> float:
        if self.softmax_scale is not None:
            return self.softmax_scale
        if self.mla:
            return (self.qk_nope_head_dim + self.qk_rope_head_dim) ** -0.5
        return self.head_dim ** -0.5


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_attention(
    key: jax.Array, cfg: AttentionConfig, acfg: AdapterConfig, dtype=jnp.bfloat16
) -> Tuple[Dict, Dict]:
    if cfg.mla:
        return _init_mla(key, cfg, acfg, dtype)
    keys = jax.random.split(key, 4)
    base: Dict = {}
    adapters: Dict = {}
    base["q"], adapters["q"] = L.init_linear(
        keys[0], cfg.d_model, cfg.num_heads * cfg.head_dim, acfg, dtype=dtype
    )
    base["k"], adapters["k"] = L.init_linear(
        keys[1], cfg.d_model, cfg.num_kv_heads * cfg.head_dim, acfg, dtype=dtype
    )
    base["v"], adapters["v"] = L.init_linear(
        keys[2], cfg.d_model, cfg.num_kv_heads * cfg.head_dim, acfg, dtype=dtype
    )
    base["o"], adapters["o"] = L.init_linear(
        keys[3], cfg.num_heads * cfg.head_dim, cfg.d_model, acfg, dtype=dtype
    )
    if cfg.qk_norm:
        base["q_norm"] = L.init_rmsnorm(cfg.head_dim)
        base["k_norm"] = L.init_rmsnorm(cfg.head_dim)
    return base, adapters


def _init_mla(key, cfg: AttentionConfig, acfg, dtype):
    keys = jax.random.split(key, 6)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    base: Dict = {}
    adapters: Dict = {}
    # q projection (lite model: full-rank q)
    base["q"], adapters["q"] = L.init_linear(
        keys[0], cfg.d_model, cfg.num_heads * qk_head, acfg, dtype=dtype
    )
    # joint KV compression: d_model -> kv_lora_rank (+ shared rope key dims)
    base["kv_down"], adapters["kv_down"] = L.init_linear(
        keys[1],
        cfg.d_model,
        cfg.kv_lora_rank + cfg.qk_rope_head_dim,
        acfg,
        dtype=dtype,
    )
    base["kv_norm"] = L.init_rmsnorm(cfg.kv_lora_rank)
    # up-projection from the latent to per-head K (nope part) and V
    base["k_up"], adapters["k_up"] = L.init_linear(
        keys[2],
        cfg.kv_lora_rank,
        cfg.num_heads * cfg.qk_nope_head_dim,
        acfg,
        dtype=dtype,
    )
    base["v_up"], adapters["v_up"] = L.init_linear(
        keys[3], cfg.kv_lora_rank, cfg.num_heads * cfg.v_head_dim, acfg, dtype=dtype
    )
    base["o"], adapters["o"] = L.init_linear(
        keys[4], cfg.num_heads * cfg.v_head_dim, cfg.d_model, acfg, dtype=dtype
    )
    return base, adapters


# ---------------------------------------------------------------------------
# projections (fused serve-time leaves dispatch here)
# ---------------------------------------------------------------------------
#
# ``prepare_base_for_serve`` (substrate/prepared.py) may replace the
# per-leaf q/k/v (and the MLA pairs) with a single fused leaf over the
# concatenated output dim — one kernel launch instead of three at decode
# shapes. The fused leaf only ever exists for SELF-attention (q/k/v share
# the input); cross-attention trees keep per-leaf projections. Splitting
# uses the config's head layout, so the math is unchanged.


def _qkv_proj(x, kv_src, base, a, cfg: AttentionConfig, acfg):
    if "_qkv" in base:
        qkv = L.linear(x, base["_qkv"], None, acfg)
        nq = cfg.num_heads * cfg.head_dim
        nkv = cfg.num_kv_heads * cfg.head_dim
        return qkv[..., :nq], qkv[..., nq : nq + nkv], qkv[..., nq + nkv :]
    return (
        L.linear(x, base["q"], a.get("q"), acfg),
        L.linear(kv_src, base["k"], a.get("k"), acfg),
        L.linear(kv_src, base["v"], a.get("v"), acfg),
    )


def _mla_q_kv_proj(x, base, a, cfg: AttentionConfig, acfg):
    """(q, joint-kv) — fused as one launch when prepared ("_q_kvd")."""
    if "_q_kvd" in base:
        out = L.linear(x, base["_q_kvd"], None, acfg)
        nq = cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        return out[..., :nq], out[..., nq:]
    return (
        L.linear(x, base["q"], a.get("q"), acfg),
        L.linear(x, base["kv_down"], a.get("kv_down"), acfg),
    )


def _mla_up_proj(c_kv, base, a, cfg: AttentionConfig, acfg):
    """(k_nope, v) from the latent — fused when prepared ("_kup_vup")."""
    if "_kup_vup" in base:
        out = L.linear(c_kv, base["_kup_vup"], None, acfg)
        nk = cfg.num_heads * cfg.qk_nope_head_dim
        return out[..., :nk], out[..., nk:]
    return (
        L.linear(c_kv, base["k_up"], a.get("k_up"), acfg),
        L.linear(c_kv, base["v_up"], a.get("v_up"), acfg),
    )


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def causal_mask(q_len: int, kv_len: int, window: Optional[int] = None):
    """(q_len, kv_len) boolean mask. Queries are the *last* q_len positions
    of the kv sequence (supports decode where q_len=1, kv_len=cache)."""
    q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
    kv_pos = jnp.arange(kv_len)[None, :]
    mask = kv_pos <= q_pos
    if window is not None:
        mask = mask & (kv_pos > q_pos - window)
    return mask


def _sdpa(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, T, KVH, hd)
    v: jax.Array,  # (B, T, KVH, vd)
    scale: float,
    mask: Optional[jax.Array],  # broadcastable to (B, H, S, T)
) -> jax.Array:
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, s, kvh, groups, hd)
    # The (S, T) logits/probs tensors dominate HBM traffic for long
    # sequences; they stay in the compute dtype (bf16) with an f32
    # max/sum reduction — halves the dominant memory-roofline term vs
    # f32 softmax at <=0.5% probability error over T=4k (§Perf H-8).
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k) * jnp.asarray(
        scale, q.dtype
    )
    if mask is not None:
        # mask: (.., S, T) -> (B?, 1, 1, S, T)
        while mask.ndim < logits.ndim:
            mask = mask[None]
        logits = jnp.where(mask, logits, jnp.asarray(NEG_INF, logits.dtype))
    lmax = jax.lax.stop_gradient(
        jnp.max(logits.astype(jnp.float32), axis=-1, keepdims=True)
    )
    p = jnp.exp(logits - lmax.astype(logits.dtype))
    denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
    probs = (p / denom.astype(p.dtype)).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, -1)


# ---------------------------------------------------------------------------
# forward (full-sequence: training / prefill)
# ---------------------------------------------------------------------------


def attention(
    x: jax.Array,  # (B, S, d)
    base: Dict,
    adapters: Optional[Dict],
    cfg: AttentionConfig,
    acfg: AdapterConfig,
    positions: Optional[jax.Array] = None,
    kv_input: Optional[jax.Array] = None,  # encoder output for cross-attn
    mask: Optional[jax.Array] = None,  # override (encoder bidir / prefix-LM)
    return_kv: bool = False,  # also return the post-rope K/V for prefill
) -> jax.Array:
    if cfg.mla:
        return _mla_attention(
            x, base, adapters, cfg, acfg, positions, mask, return_kv=return_kv
        )
    a = adapters or {}
    b_, s, _ = x.shape
    kv_src = kv_input if cfg.is_cross else x
    t = kv_src.shape[1]
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv_proj(x, kv_src, base, a, cfg, acfg)
    q = q.reshape(b_, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b_, t, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b_, t, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, base["q_norm"])
        k = L.rms_norm(k, base["k_norm"])
    if not cfg.is_cross:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if mask is None:
            mask = causal_mask(s, t, cfg.window)
    # cross-attention default: full bidirectional over encoder states
    out = _sdpa(q, k, v, cfg.scale, mask)
    y = L.linear(out.reshape(b_, s, -1), base["o"], a.get("o"), acfg)
    if return_kv:
        return y, {"k": k, "v": v}
    return y


def _mla_attention(
    x, base, adapters, cfg: AttentionConfig, acfg, positions, mask=None,
    return_kv: bool = False,
):
    a = adapters or {}
    b_, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q, kv = _mla_q_kv_proj(x, base, a, cfg, acfg)
    q = q.reshape(b_, s, cfg.num_heads, qk_head)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = L.apply_rope(q[..., cfg.qk_nope_head_dim :], positions, cfg.rope_theta)
    c_kv = L.rms_norm(kv[..., : cfg.kv_lora_rank], base["kv_norm"])
    k_rope = kv[..., cfg.kv_lora_rank :]  # (B, S, rope_dim) shared across heads
    k_rope = L.apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)
    k_nope, v = _mla_up_proj(c_kv, base, a, cfg, acfg)
    k_nope = k_nope.reshape(b_, s, cfg.num_heads, cfg.qk_nope_head_dim)
    v = v.reshape(b_, s, cfg.num_heads, cfg.v_head_dim)
    k_rope_b = jnp.broadcast_to(
        k_rope, (b_, s, cfg.num_heads, cfg.qk_rope_head_dim)
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    if mask is None:
        mask = causal_mask(s, s, cfg.window)
    out = _sdpa(q_full, k_full, v, cfg.scale, mask)
    y = L.linear(out.reshape(b_, s, -1), base["o"], a.get("o"), acfg)
    if return_kv:
        # the decode cache holds the compressed latent + shared rope key,
        # both post-norm/post-rope — exactly what _mla_decode writes
        return y, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    return y


# ---------------------------------------------------------------------------
# cross-attention cache (encoder-decoder serving)
# ---------------------------------------------------------------------------
#
# Encoder K/V never change after admission: they are projections of the
# (frozen) encoder output. The serving cache therefore stores them once
# per decoder layer ("xk"/"xv", post-qk-norm, no rope — exactly what
# ``attention(kv_input=...)`` computes inline) and masks the padded
# source tail per slot by ``enc_len``. Masked logits hit NEG_INF ->
# exp() == 0.0 exactly in the f32 softmax sum, so a padded buffer is
# bitwise the exact-length inline computation.


def init_cross_cache(
    batch: int, src_len: int, cfg: AttentionConfig, dtype=jnp.bfloat16
) -> Dict:
    """Per-layer encoder K/V lines for one decoder layer."""
    shape = (batch, src_len, cfg.num_kv_heads, cfg.head_dim)
    return {"xk": jnp.zeros(shape, dtype), "xv": jnp.zeros(shape, dtype)}


def cross_kv(
    enc_out: jax.Array,  # (B, S_src, d)
    base: Dict,
    adapters: Optional[Dict],
    cfg: AttentionConfig,
    acfg: AdapterConfig,
) -> Tuple[jax.Array, jax.Array]:
    """The cacheable half of cross-attention: K/V over the encoder
    output, identical to what ``attention(kv_input=enc_out)`` computes
    (post-norm, never roped). Cross trees keep per-leaf projections (no
    fused "_qkv" leaf), so the projections are addressed directly."""
    a = adapters or {}
    b_, t, _ = enc_out.shape
    k = L.linear(enc_out, base["k"], a.get("k"), acfg)
    v = L.linear(enc_out, base["v"], a.get("v"), acfg)
    k = k.reshape(b_, t, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b_, t, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = L.rms_norm(k, base["k_norm"])
    return k, v


def cross_attention_cached(
    x: jax.Array,  # (B, S, d) — decode (S=1) or a prefill chunk
    cache: Dict,   # layer cache holding "xk"/"xv" (B, T_src, kvh, hd)
    enc_len: jax.Array,  # (B,) int32 valid source length per slot
    base: Dict,
    adapters: Optional[Dict],
    cfg: AttentionConfig,
    acfg: AdapterConfig,
) -> jax.Array:
    """Cross-attention against cached encoder K/V, masked per slot by
    ``enc_len``. Bitwise the inline ``attention(kv_input=enc_out)`` for
    the valid source positions (padded tail softmaxes to exact zero)."""
    a = adapters or {}
    b_, s, _ = x.shape
    q = L.linear(x, base["q"], a.get("q"), acfg)
    q = q.reshape(b_, s, cfg.num_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, base["q_norm"])
    t = cache["xk"].shape[1]
    valid = jnp.arange(t)[None, :] < enc_len[:, None]  # (B, T_src)
    out = _sdpa(
        q, cache["xk"], cache["xv"], cfg.scale,
        valid[:, None, None, None, :],
    )
    return L.linear(out.reshape(b_, s, -1), base["o"], a.get("o"), acfg)


# ---------------------------------------------------------------------------
# decode path with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(
    batch: int, max_len: int, cfg: AttentionConfig, dtype=jnp.bfloat16
) -> Dict:
    """Cache for one layer. Sliding-window layers allocate only the window
    (rolling buffer); MLA caches the compressed latent + shared rope key."""
    if cfg.is_cross:
        return {}
    if cfg.mla:
        length = max_len if cfg.window is None else min(cfg.window, max_len)
        return {
            "c_kv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
        }
    length = max_len if cfg.window is None else min(cfg.window, max_len)
    return {
        "k": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, length, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def _as_pos_vector(pos: jax.Array, batch: int) -> jax.Array:
    """Normalize ``pos`` to a (B,) int32 vector of per-slot clocks.
    Scalar ``pos`` (the legacy lockstep-batch calling convention)
    broadcasts to every row."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((batch,), pos, jnp.int32)
    return pos


def _cache_write(buf: jax.Array, val: jax.Array, pos: jax.Array) -> jax.Array:
    """Write one position per batch row into a (possibly rolling) cache
    buffer. ``pos`` is a (B,) vector of per-slot clocks, so each row of a
    continuous batch can sit at a different sequence offset."""
    length = buf.shape[1]
    slot = pos % length  # (B,)
    rows = jnp.arange(buf.shape[0])
    return buf.at[rows, slot].set(val[:, 0])


def _cache_mask(pos: jax.Array, length: int, window: Optional[int]):
    """Per-slot valid-entry mask for a rolling cache after writing
    position ``pos[b]`` in row ``b``. Entries with index > pos (not yet
    written) are invalid; for windowed caches every slot is valid once
    that row's clock passes the buffer length (wrap-around)."""
    idx = jnp.arange(length)[None, :]
    valid = idx <= pos[:, None]
    if window is not None:
        valid = valid | (pos >= length)[:, None]
    return valid  # (B, length)


def prefill_kv_cache(
    kv: Dict, batch: int, max_len: int, cfg: AttentionConfig, dtype=jnp.bfloat16
) -> Dict:
    """Scatter full-sequence prefill K/V (or MLA latents) into a fresh
    decode cache. For rolling (windowed) buffers only the last ``length``
    positions land, at their wrapped indices — the same layout
    ``_cache_write`` would have produced stepping token by token."""
    cache = init_kv_cache(batch, max_len, cfg, dtype)
    s = next(iter(kv.values())).shape[1]
    out = {}
    for name, buf in cache.items():
        length = buf.shape[1]
        start = max(0, s - length)
        idx = jnp.arange(start, s)
        out[name] = buf.at[:, idx % length].set(
            kv[name][:, start:].astype(buf.dtype)
        )
    return out


def decode_attention(
    x: jax.Array,  # (B, 1, d)
    cache: Dict,
    pos: jax.Array,  # (B,) int32 per-slot clocks (scalar broadcasts)
    base: Dict,
    adapters: Optional[Dict],
    cfg: AttentionConfig,
    acfg: AdapterConfig,
) -> Tuple[jax.Array, Dict]:
    a = adapters or {}
    b_ = x.shape[0]
    pos = _as_pos_vector(pos, b_)
    positions = pos[:, None]  # (B, 1)
    if cfg.mla:
        return _mla_decode(x, cache, pos, positions, base, a, cfg, acfg)
    q, k, v = _qkv_proj(x, x, base, a, cfg, acfg)
    q = q.reshape(b_, 1, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b_, 1, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b_, 1, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, base["q_norm"])
        k = L.rms_norm(k, base["k_norm"])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    k_buf = _cache_write(cache["k"], k, pos)
    v_buf = _cache_write(cache["v"], v, pos)
    valid = _cache_mask(pos, k_buf.shape[1], cfg.window)  # (B, T)
    out = _sdpa(q, k_buf, v_buf, cfg.scale, valid[:, None, None, None, :])
    y = L.linear(out.reshape(b_, 1, -1), base["o"], a.get("o"), acfg)
    return y, {"k": k_buf, "v": v_buf}


def _mla_decode(x, cache, pos, positions, base, a, cfg: AttentionConfig, acfg):
    b_ = x.shape[0]
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q, kv = _mla_q_kv_proj(x, base, a, cfg, acfg)
    q = q.reshape(b_, 1, cfg.num_heads, qk_head)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = L.apply_rope(q[..., cfg.qk_nope_head_dim :], positions, cfg.rope_theta)
    c_kv = L.rms_norm(kv[..., : cfg.kv_lora_rank], base["kv_norm"])
    k_rope_new = L.apply_rope(
        kv[..., None, cfg.kv_lora_rank :], positions, cfg.rope_theta
    )[:, :, 0, :]
    c_buf = _cache_write(cache["c_kv"], c_kv, pos)
    r_buf = _cache_write(cache["k_rope"], k_rope_new, pos)
    # Expand cached latents through the up-projections. (The latency-optimal
    # "absorbed" form folds k_up into q — left as a hillclimb; this form is
    # the reference semantics.)
    t = c_buf.shape[1]
    k_nope, v = _mla_up_proj(c_buf, base, a, cfg, acfg)
    k_nope = k_nope.reshape(b_, t, cfg.num_heads, cfg.qk_nope_head_dim)
    v = v.reshape(b_, t, cfg.num_heads, cfg.v_head_dim)
    k_rope_b = jnp.broadcast_to(
        r_buf[:, :, None, :], (b_, t, cfg.num_heads, cfg.qk_rope_head_dim)
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    valid = _cache_mask(pos, t, cfg.window)  # (B, T)
    out = _sdpa(q_full, k_full, v, cfg.scale, valid[:, None, None, None, :])
    y = L.linear(out.reshape(b_, 1, -1), base["o"], a.get("o"), acfg)
    return y, {"c_kv": c_buf, "k_rope": r_buf}


# ---------------------------------------------------------------------------
# chunked prefill (advance a decode cache by a whole prompt chunk)
# ---------------------------------------------------------------------------
#
# ``chunk_attention`` is the C-token generalisation of
# ``decode_attention``: scatter the chunk's K/V into the live cache at
# absolute positions, attend each chunk query against everything
# written so far. Because every projection/rope/softmax is row- and
# position-independent and masked tails softmax to exact zero, a prompt
# processed chunk-by-chunk is bitwise the fused ``prefill`` — pinned in
# tests/test_engine.py.
#
# Rolling (sliding-window) caches need care when a chunk is longer than
# the window: a later in-chunk position would overwrite the wrapped slot
# an earlier query still reads. So windowed layers attend on a gathered
# absolute-position *canvas* (size max_len) and gather the freshest
# residue per slot back into the rolling buffer afterwards.


def chunk_attention(
    x: jax.Array,  # (B, C, d) — embedded chunk, padded tail allowed
    cache: Dict,
    pos0: jax.Array,  # (B,) absolute position of the chunk's first token
    n_valid: jax.Array,  # (B,) real tokens in this chunk (rest is padding)
    base: Dict,
    adapters: Optional[Dict],
    cfg: AttentionConfig,
    acfg: AdapterConfig,
    *,
    max_len: int,
    prefix: int = 0,  # prefix-LM boundary (vision tokens attend bidir)
) -> Tuple[jax.Array, Dict]:
    a = adapters or {}
    b_, c, _ = x.shape
    pos0 = _as_pos_vector(pos0, b_)
    n_valid = _as_pos_vector(n_valid, b_)
    i = jnp.arange(c)[None, :]
    positions = pos0[:, None] + i  # (B, C) absolute positions
    if cfg.mla:
        return _mla_chunk(
            x, cache, positions, i, n_valid, base, a, cfg, acfg, prefix
        )
    q, k, v = _qkv_proj(x, x, base, a, cfg, acfg)
    q = q.reshape(b_, c, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b_, c, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b_, c, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, base["q_norm"])
        k = L.rms_norm(k, base["k_norm"])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    rows = jnp.arange(b_)[:, None]
    length = cache["k"].shape[1]
    rolling = length < max_len
    # Padded tail rows scatter out of range -> dropped.
    drop_to = max_len if rolling else length
    wpos = jnp.where(i < n_valid[:, None], positions, drop_to)
    t = max_len if rolling else length
    j = jnp.arange(t)[None, None, :]
    allow = j <= positions[:, :, None]  # (B, C, T)
    if cfg.window is not None:
        allow = allow & (j > positions[:, :, None] - cfg.window)
    if prefix:
        if rolling:
            raise ValueError("prefix-LM chunks need a non-rolling cache")
        allow = allow | (j < prefix)
    if not rolling:
        k_buf = cache["k"].at[rows, wpos].set(k, mode="drop")
        v_buf = cache["v"].at[rows, wpos].set(v, mode="drop")
        out = _sdpa(q, k_buf, v_buf, cfg.scale, allow[:, None, None])
        new = {"k": k_buf, "v": v_buf}
    else:
        # Absolute canvas: slot j holds the rolling residue of j.
        jj = jnp.arange(max_len)
        k_can = cache["k"][:, jj % length].at[rows, wpos].set(k, mode="drop")
        v_can = cache["v"][:, jj % length].at[rows, wpos].set(v, mode="drop")
        out = _sdpa(q, k_can, v_can, cfg.scale, allow[:, None, None])
        # Gather the freshest written position per residue class back.
        # Slots this chunk never reached keep their old value (src walks
        # back to the previous occupant); slots ahead of the clock clip
        # to an arbitrary canvas entry — they stay masked until the
        # row's clock wraps, by which point they are genuinely written.
        pos_max = pos0 + n_valid - 1  # (B,)
        m = jnp.arange(length)[None, :]
        src = pos_max[:, None] - ((pos_max[:, None] - m) % length)
        src = jnp.clip(src, 0, max_len - 1)
        new = {"k": k_can[rows, src], "v": v_can[rows, src]}
    y = L.linear(out.reshape(b_, c, -1), base["o"], a.get("o"), acfg)
    return y, new


def _mla_chunk(x, cache, positions, i, n_valid, base, a, cfg, acfg, prefix):
    """Chunk step for MLA caches: scatter the post-norm latent + shared
    rope key at absolute positions, then up-project the full buffer like
    ``_mla_decode``. MLA layers are never windowed here (deepseek-v2 is
    global), so the latent buffer is always full-length."""
    b_, c, _ = x.shape
    length = cache["c_kv"].shape[1]
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q, kv = _mla_q_kv_proj(x, base, a, cfg, acfg)
    q = q.reshape(b_, c, cfg.num_heads, qk_head)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = L.apply_rope(q[..., cfg.qk_nope_head_dim :], positions, cfg.rope_theta)
    c_kv = L.rms_norm(kv[..., : cfg.kv_lora_rank], base["kv_norm"])
    k_rope_new = L.apply_rope(
        kv[..., None, cfg.kv_lora_rank :], positions, cfg.rope_theta
    )[:, :, 0, :]
    rows = jnp.arange(b_)[:, None]
    wpos = jnp.where(i < n_valid[:, None], positions, length)
    c_buf = cache["c_kv"].at[rows, wpos].set(c_kv, mode="drop")
    r_buf = cache["k_rope"].at[rows, wpos].set(k_rope_new, mode="drop")
    k_nope, v = _mla_up_proj(c_buf, base, a, cfg, acfg)
    k_nope = k_nope.reshape(b_, length, cfg.num_heads, cfg.qk_nope_head_dim)
    v = v.reshape(b_, length, cfg.num_heads, cfg.v_head_dim)
    k_rope_b = jnp.broadcast_to(
        r_buf[:, :, None, :], (b_, length, cfg.num_heads, cfg.qk_rope_head_dim)
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    j = jnp.arange(length)[None, None, :]
    allow = j <= positions[:, :, None]
    if prefix:
        allow = allow | (j < prefix)
    out = _sdpa(q_full, k_full, v, cfg.scale, allow[:, None, None])
    y = L.linear(out.reshape(b_, c, -1), base["o"], a.get("o"), acfg)
    return y, {"c_kv": c_buf, "k_rope": r_buf}
