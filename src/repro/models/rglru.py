"""RG-LRU recurrent block (recurrentgemma-9b, Griffin arXiv:2402.19427).

Recurrence (per channel, elementwise state — parallelizable with a single
associative scan over the whole sequence):

    r_t = sigmoid(W_a x_t)                      (recurrence gate)
    i_t = sigmoid(W_x x_t)                      (input gate)
    a_t = a ^ (c * r_t),  a = sigmoid(Lambda)   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The full residual block is: linear-in (x, y branches), depthwise causal
conv on the recurrent branch, RG-LRU, gated merge, linear-out — all dense
projections RimcLinear (DoRA side-cars apply; DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dora import AdapterConfig
from repro.models import layers as L
from repro.models.ssm import _causal_conv, conv_tail

_C_FACTOR = 8.0
_MAX_SQRT_GRADIENT = 1000.0


@dataclasses.dataclass(frozen=True)
class RglruConfig:
    d_model: int
    d_rnn: int  # lru width
    conv_kernel: int = 4


def init_rglru(
    key: jax.Array, cfg: RglruConfig, acfg: AdapterConfig, dtype=jnp.bfloat16
) -> Tuple[Dict, Dict]:
    keys = jax.random.split(key, 6)
    base: Dict = {}
    adapters: Dict = {}
    base["in_x"], adapters["in_x"] = L.init_linear(
        keys[0], cfg.d_model, cfg.d_rnn, acfg, dtype=dtype
    )
    base["in_y"], adapters["in_y"] = L.init_linear(
        keys[1], cfg.d_model, cfg.d_rnn, acfg, dtype=dtype
    )
    base["gate_a"], adapters["gate_a"] = L.init_linear(
        keys[2], cfg.d_rnn, cfg.d_rnn, acfg, dtype=dtype
    )
    base["gate_x"], adapters["gate_x"] = L.init_linear(
        keys[3], cfg.d_rnn, cfg.d_rnn, acfg, dtype=dtype
    )
    base["out"], adapters["out"] = L.init_linear(
        keys[4], cfg.d_rnn, cfg.d_model, acfg, dtype=dtype
    )
    base["conv_w"] = (
        jax.random.normal(keys[5], (cfg.conv_kernel, cfg.d_rnn), jnp.float32)
        * (cfg.conv_kernel ** -0.5)
    )
    base["conv_b"] = jnp.zeros((cfg.d_rnn,), jnp.float32)
    # Lambda parameterizes a = sigmoid(Lambda); init so a^c in [0.9, 0.999]
    u = jnp.linspace(0.9, 0.999, cfg.d_rnn)
    a = u ** (1.0 / _C_FACTOR)
    base["lambda_p"] = jnp.log(a / (1.0 - a))
    return base, adapters


def _rglru_scan(
    x: jax.Array,  # (B, S, d_rnn) — conv'd branch input
    base: Dict,
    a_: Dict,
    acfg: AdapterConfig,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    r = jax.nn.sigmoid(
        L.linear(x, base["gate_a"], a_.get("gate_a"), acfg).astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        L.linear(x, base["gate_x"], a_.get("gate_x"), acfg).astype(jnp.float32)
    )
    a_base = jax.nn.sigmoid(base["lambda_p"].astype(jnp.float32))[None, None]
    log_a = _C_FACTOR * r * jnp.log(a_base)
    a_t = jnp.exp(log_a)
    gated_x = i * x.astype(jnp.float32)
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b_t = multiplier * gated_x

    def combine(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, ar * bl + br

    a_cum, b_cum = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
    if h0 is not None:
        h = a_cum * h0[:, None] + b_cum
    else:
        h = b_cum
    return h, h[:, -1]


def rglru_block(
    x: jax.Array,  # (B, S, d_model)
    base: Dict,
    adapters: Optional[Dict],
    cfg: RglruConfig,
    acfg: AdapterConfig,
    *,
    return_state: bool = False,
) -> jax.Array:
    a_ = adapters or {}
    xb_raw = L.linear(x, base["in_x"], a_.get("in_x"), acfg)
    yb = jax.nn.gelu(L.linear(x, base["in_y"], a_.get("in_y"), acfg))
    xb = _causal_conv(xb_raw, base["conv_w"], base["conv_b"])
    h, h_last = _rglru_scan(xb, base, a_, acfg)
    merged = h.astype(x.dtype) * yb
    out = L.linear(merged, base["out"], a_.get("out"), acfg)
    if return_state:
        return out, {"h": h_last, "conv": conv_tail(xb_raw, cfg.conv_kernel)}
    return out


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_rglru_cache(batch: int, cfg: RglruConfig, dtype=jnp.float32) -> Dict:
    return {
        "h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_rnn), dtype),
    }


def rglru_decode(
    x: jax.Array,  # (B, 1, d_model)
    cache: Dict,
    base: Dict,
    adapters: Optional[Dict],
    cfg: RglruConfig,
    acfg: AdapterConfig,
) -> Tuple[jax.Array, Dict]:
    a_ = adapters or {}
    xb = L.linear(x, base["in_x"], a_.get("in_x"), acfg)  # (B,1,d_rnn)
    yb = jax.nn.gelu(L.linear(x, base["in_y"], a_.get("in_y"), acfg))
    window = jnp.concatenate([cache["conv"], xb.astype(cache["conv"].dtype)], axis=1)
    conv_out = (
        jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), base["conv_w"])
        + base["conv_b"]
    )
    xb1 = conv_out[:, None, :].astype(x.dtype)
    r = jax.nn.sigmoid(
        L.linear(xb1, base["gate_a"], a_.get("gate_a"), acfg).astype(jnp.float32)
    )[:, 0]
    i = jax.nn.sigmoid(
        L.linear(xb1, base["gate_x"], a_.get("gate_x"), acfg).astype(jnp.float32)
    )[:, 0]
    a_base = jax.nn.sigmoid(base["lambda_p"].astype(jnp.float32))[None]
    log_a = _C_FACTOR * r * jnp.log(a_base)
    a_t = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a_t * cache["h"] + mult * (i * xb1[:, 0].astype(jnp.float32))
    merged = h[:, None, :].astype(x.dtype) * yb
    out = L.linear(merged, base["out"], a_.get("out"), acfg)
    return out, {"h": h, "conv": window[:, 1:]}
