"""Versioned calibration registry: stability metrics, reference
promotion, and fleet-wide DoRA warm-start.

Every ``Deployment.calibrate`` / ``Fleet.calibrate`` run can be
persisted as a versioned, content-addressed artifact keyed by ``(cfg
fingerprint, backend, drift/fault signature)``; stability metrics
(percentile drift, JSD, ``is_stable``) decide when a key's promoted
reference is replaced; and new or recalibrating chips warm-start their
adapters + optimizer from the nearest stable reference instead of from
zeros — turning one-off calibrations into a fleet-wide amortized asset:

    from repro.registry import CalibrationRegistry

    registry = CalibrationRegistry("/var/cal-registry")
    dep.calibrate(10, registry=registry)                  # record v1
    dep.advance(hours=168)
    dep.calibrate(10, registry=registry, warm_start=True)  # seeded, fast

See ``registry/store.py`` for the artifact layout, ``registry/metrics``
for the drift metrics, ``registry/policy`` for promotion rules, and
``registry/warmstart`` for the nearest-reference lookup.
"""
from repro.registry.metrics import (  # noqa: F401
    DEFAULT_THRESHOLDS,
    StabilityMetrics,
    StabilityThresholds,
    adapter_samples,
    is_stable_under,
    jensen_shannon,
    stability_metrics,
)
from repro.registry.policy import PromotionDecision, PromotionPolicy  # noqa: F401
from repro.registry.store import (  # noqa: F401
    ArtifactRecord,
    CalibrationRegistry,
    RegistryKey,
    cfg_fingerprint,
    signature_key,
)
from repro.registry.warmstart import (  # noqa: F401
    drift_signature,
    nearest_reference,
    seed_deployment,
    seed_fleet,
    signature_distance,
)
