"""Nearest-stable-reference warm-start for DoRA calibration.

The paper's calibration cost (10 samples x ~20 epochs) is paid from
zero-initialized (output-preserving) adapters on every recalibration.
But drift compensation transfers across nearby drift states when
factored correctly (VeRA+, arxiv 2603.26016): a chip recalibrating after
one more drift epoch starts from an optimum a small perturbation away
from its LAST one, and a freshly joined chip starts closer to a sibling
chip's compensation than to zero. This module turns the registry's
promoted references into those starting points:

* ``drift_signature`` — a small float vector summarizing a device's
  drift/fault state: a DEVICE feature (hash of the programming key,
  scaled by ``DEVICE_WEIGHT``) plus the physical drift scale
  (``rram.drift_sigma`` over the elapsed field hours), a log-time
  feature, the drift-event count, and the fault-event count. The device
  feature dominates cross-device distances, so a chip's OWN history wins
  the lookup whenever it exists; a virgin chip (no own artifacts) falls
  back to the nearest sibling reference deterministically.
* ``nearest_reference`` — Euclidean nearest promoted reference among
  all keys under ``(cfg, backend)``; ties break on the lexicographic
  signature key, so the lookup is a pure function of the registry
  contents.
* ``seed_deployment`` / ``seed_fleet`` — seed ``CalibState`` adapters
  AND optimizer moments from the reference instead of zeros; the fleet
  form resolves per-chip nearest references and scatters them into the
  stacked trees in one batched seed (one ``.at[idx].set`` per leaf).
"""
from __future__ import annotations

import zlib
from typing import Any, List, Optional, Sequence

import numpy as np

from repro.core import rram
from repro.registry.store import ArtifactRecord, CalibrationRegistry

Pytree = Any

# Scale of the device-identity component relative to the drift-state
# components. Per-cycle drift-state distances are O(relative_drift *
# log-time increment) ~ 1e-2; two distinct devices differ by up to
# DEVICE_WEIGHT here, so own-history references dominate whenever they
# exist without drowning the drift components for virgin chips.
DEVICE_WEIGHT = 0.25

# Normalizers keeping the time/event components commensurate with the
# sigma component (~1e-1 over realistic lifetimes).
_LOG_TIME_SCALE = 1.0 / 16.0
_EVENT_SCALE = 1.0 / 32.0


def device_feature(program_key) -> float:
    """Deterministic device-identity feature in ``[0, DEVICE_WEIGHT)``:
    a crc32 of the programming key words. Not a metric — an identity
    separator that keeps different devices' signatures apart."""
    words = np.asarray(program_key).astype(np.uint32)
    return DEVICE_WEIGHT * (zlib.crc32(words.tobytes()) / 2.0 ** 32)


def drift_signature(
    rcfg: rram.RramConfig,
    program_key,
    *,
    field_hours: float,
    drift_events: int,
    fault_events: int = 0,
) -> np.ndarray:
    """The registry signature of one device's drift/fault state. Two
    identical lifecycles (same programming key, same history) produce the
    same vector — and hence the same registry key — while nearby drift
    states land nearby in Euclidean distance. Fault events weigh 1.0
    each: a faulted chip's compensation should never silently seed a
    healthy one."""
    return np.asarray(
        [
            device_feature(program_key),
            rram.drift_sigma(rcfg, float(field_hours)),
            np.log1p(float(field_hours)) * _LOG_TIME_SCALE,
            float(drift_events) * _EVENT_SCALE,
            float(fault_events),
        ],
        np.float64,
    )


def signature_distance(a, b) -> float:
    """Euclidean distance between two signature vectors."""
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    if a.shape != b.shape:
        return float("inf")
    return float(np.sqrt(np.sum((a - b) ** 2)))


def nearest_reference(
    registry: CalibrationRegistry, cfg, backend: str, signature,
) -> Optional[ArtifactRecord]:
    """The promoted reference nearest to ``signature`` among every key
    under ``(cfg, backend)``. Deterministic: candidates are ranked by
    ``(distance, signature key)`` — repeated lookups against unchanged
    registry contents always return the same record."""
    refs = registry.references(cfg, backend)
    if not refs:
        return None
    ranked = sorted(
        refs,
        key=lambda r: (signature_distance(signature, r.signature),
                       r.key.sig_key),
    )
    best = ranked[0]
    if signature_distance(signature, best.signature) == float("inf"):
        return None
    return best


def seed_deployment(dep, registry: CalibrationRegistry) -> Optional[ArtifactRecord]:
    """Warm-start one deployment: find the nearest stable reference for
    its current drift signature and seed its adapters + optimizer from
    the recorded artifact (bitwise as recorded). Returns the record, or
    None when the registry has nothing usable (the caller falls back to
    the cold zero-initialized start)."""
    from repro.optim.adam import adamw_init

    rec = nearest_reference(
        registry, dep.cfg, dep.backend, dep.drift_signature()
    )
    if rec is None:
        return None
    like = {
        "adapters": dep.adapters,
        "opt": dep.opt_state if dep.opt_state is not None
        else adamw_init(dep.adapters),
    }
    trees = registry.load(rec, like)
    dep.adapters = trees["adapters"]
    dep.opt_state = trees["opt"]
    return rec


def seed_fleet(
    fleet, registry: CalibrationRegistry, chips: Sequence[int],
) -> List[Optional[ArtifactRecord]]:
    """Warm-start ``chips`` of a fleet in ONE batched seed: resolve each
    chip's nearest stable reference (per-chip drift signatures), load
    every distinct artifact once, stack the per-chip reference trees, and
    scatter them into the fleet's stacked adapters/optimizer with a
    single ``.at[idx].set`` per leaf. Chips without a usable reference
    keep their current (cold) state. Returns the per-chip records
    (None: cold)."""
    import jax
    import jax.numpy as jnp

    from repro.optim.adam import adamw_init

    recs: List[Optional[ArtifactRecord]] = [
        nearest_reference(
            registry, fleet.cfg, fleet.backend, fleet.chip_signature(c)
        )
        for c in chips
    ]
    hits = [(c, r) for c, r in zip(chips, recs) if r is not None]
    if not hits:
        return recs
    if fleet.opt_state is None:
        fleet.opt_state = jax.vmap(adamw_init)(fleet.adapters)
    like = {
        "adapters": jax.tree_util.tree_map(lambda x: x[0], fleet.adapters),
        "opt": jax.tree_util.tree_map(lambda x: x[0], fleet.opt_state),
    }
    cache = {}
    loaded = []
    for _, rec in hits:
        k = (rec.key.name, rec.version)
        if k not in cache:
            cache[k] = registry.load(rec, like)
        loaded.append(cache[k])
    idx = jnp.asarray([c for c, _ in hits], jnp.int32)
    stacked = {
        name: jax.tree_util.tree_map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]),
            *[t[name] for t in loaded],
        )
        for name in ("adapters", "opt")
    }
    fleet.adapters = jax.tree_util.tree_map(
        lambda full, sub: full.at[idx].set(sub),
        fleet.adapters, stacked["adapters"],
    )
    fleet.opt_state = jax.tree_util.tree_map(
        lambda full, sub: full.at[idx].set(sub),
        fleet.opt_state, stacked["opt"],
    )
    return recs
