"""Reference-promotion rules for the calibration registry.

Every recorded calibration becomes a new immutable VERSION under its
``(cfg fingerprint, backend, drift signature)`` key; at most one version
per key is the promoted REFERENCE — the artifact warm-starts seed from
and fresh runs are drift-checked against. The nomarr rule set:

* **first run always promotes** — a key with no reference has nothing to
  compare against, and an unreferenced key is useless to warm-start
  from;
* **later runs promote only on instability** — if the fresh run's
  distribution still matches the reference (``metrics.is_stable``), the
  reference is still representative and churn is pure noise: keep it.
  When the fresh run has drifted away (``is_stable == False``), the
  reference is stale — promote the fresh version.

Promotion is decided here and APPLIED atomically by the store
(tmp-write + ``os.replace`` of the reference pointer), so readers never
observe a half-promoted key.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.registry.metrics import StabilityMetrics


@dataclasses.dataclass(frozen=True)
class PromotionDecision:
    promote: bool
    reason: str


class PromotionPolicy:
    """Default promote-on-instability policy (see module docstring).

    Subclass and override ``decide`` for alternative economies (e.g.
    always-promote for a registry used as a rolling cache, or
    never-promote for a frozen production registry)."""

    def decide(
        self, *, has_reference: bool, metrics: Optional[StabilityMetrics],
    ) -> PromotionDecision:
        if not has_reference:
            return PromotionDecision(True, "first run for key")
        if metrics is None:
            # reference exists but could not be compared (e.g. its sample
            # sidecar was lost) — re-promote so the key heals itself
            return PromotionDecision(True, "reference unreadable")
        if metrics.is_stable:
            return PromotionDecision(False, "reference stable")
        drifted = ", ".join(
            f"{k}={v:.4f}" for k, v in sorted(metrics.drifts().items())
        )
        return PromotionDecision(True, f"reference unstable ({drifted})")
