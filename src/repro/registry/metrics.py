"""Stability metrics over calibration-artifact distributions.

A calibration run is summarized by a 1-D sample vector (flattened
adapter values — ``adapter_samples`` — or any logit/score distribution),
and two runs are compared with the industry-standard drift metrics the
nomarr calibration system tracks per run:

* **absolute percentile drift** (``apd_p5`` / ``apd_p95``) — movement of
  the 5th / 95th percentile, normalized by the reference's p5–p95 range
  so one threshold works across adapter scales;
* **scale-range drift** (``srd``) — relative change of the p95 − p5
  range (the distribution stretching or collapsing);
* **Jensen-Shannon divergence** (``jsd``) — symmetric, bounded ([0, 1]
  in base-2), zero iff the binned distributions coincide;
* **median / IQR drift** — robust location and spread movement, same
  range normalization as the percentile drifts.

``is_stable`` is a single decision: every metric at or below its
threshold. The decision is monotone in the thresholds by construction
(loosening any threshold can only keep a stable verdict stable), which
``tests/test_properties.py`` pins.

Default thresholds (``StabilityThresholds``): percentile/median/IQR
drifts within 2 % of the reference range, range drift within 5 %, JSD
below 0.05 bits. These mirror the nomarr defaults scaled to unit-range
score distributions; registries can tighten or loosen them wholesale via
``CalibrationRegistry(thresholds=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

Pytree = Any

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class StabilityThresholds:
    """Per-metric upper bounds for the ``is_stable`` decision."""

    apd: float = 0.02      # p5/p95 drift, in units of the reference range
    srd: float = 0.05      # relative p95-p5 range drift
    jsd: float = 0.05      # Jensen-Shannon divergence (base-2 bits)
    median: float = 0.02   # median drift, in units of the reference range
    iqr: float = 0.05      # IQR drift, in units of the reference range

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


DEFAULT_THRESHOLDS = StabilityThresholds()


@dataclasses.dataclass
class StabilityMetrics:
    """One run's drift metrics against a reference run (nomarr schema)."""

    p5: float              # current 5th percentile
    p95: float             # current 95th percentile
    range: float           # p95 - p5
    apd_p5: float          # |p5 - ref_p5| / ref_range
    apd_p95: float         # |p95 - ref_p95| / ref_range
    srd: float             # |range - ref_range| / ref_range
    jsd: float             # Jensen-Shannon divergence, base-2
    median_drift: float    # |median - ref_median| / ref_range
    iqr_drift: float       # |iqr - ref_iqr| / ref_range
    is_stable: bool

    def drifts(self) -> Dict[str, float]:
        """The drift metrics the stability decision reads (name -> value)."""
        return {
            "apd_p5": self.apd_p5, "apd_p95": self.apd_p95,
            "srd": self.srd, "jsd": self.jsd,
            "median_drift": self.median_drift, "iqr_drift": self.iqr_drift,
        }

    def to_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["is_stable"] = bool(self.is_stable)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "StabilityMetrics":
        return cls(**d)


def is_stable_under(
    metrics: "StabilityMetrics", thresholds: StabilityThresholds
) -> bool:
    """Re-evaluate a metric set's stability verdict under different
    thresholds: stable iff EVERY drift metric is at or below its bound.
    Monotone: if stable under ``t`` and ``t' >= t`` componentwise, then
    stable under ``t'``."""
    return bool(
        metrics.apd_p5 <= thresholds.apd
        and metrics.apd_p95 <= thresholds.apd
        and metrics.srd <= thresholds.srd
        and metrics.jsd <= thresholds.jsd
        and metrics.median_drift <= thresholds.median
        and metrics.iqr_drift <= thresholds.iqr
    )


def jensen_shannon(
    current: np.ndarray, reference: np.ndarray, *, bins: int = 64
) -> float:
    """Jensen-Shannon divergence between two sample vectors, binned over
    their joint range. Base-2 logs: bounded in [0, 1], symmetric, and
    exactly 0 when both vectors bin identically (in particular for
    identical samples)."""
    cur = np.asarray(current, np.float64).ravel()
    ref = np.asarray(reference, np.float64).ravel()
    lo = min(cur.min(), ref.min())
    hi = max(cur.max(), ref.max())
    if hi <= lo:  # both degenerate at one point
        hi = lo + 1.0
    edges = np.linspace(lo, hi, bins + 1)
    p, _ = np.histogram(cur, bins=edges)
    q, _ = np.histogram(ref, bins=edges)
    p = p / max(p.sum(), 1)
    q = q / max(q.sum(), 1)
    m = 0.5 * (p + q)

    def kl(a, b):
        mask = a > 0
        return float(np.sum(a[mask] * np.log2(a[mask] / b[mask])))

    return max(0.0, 0.5 * kl(p, m) + 0.5 * kl(q, m))


def stability_metrics(
    current: np.ndarray,
    reference: np.ndarray,
    *,
    thresholds: StabilityThresholds = DEFAULT_THRESHOLDS,
    bins: int = 64,
) -> StabilityMetrics:
    """Compare a fresh run's sample distribution against the reference's
    and decide stability. All location/spread drifts are normalized by
    the REFERENCE p5–p95 range (floored at machine epsilon), so the same
    thresholds apply to adapter tensors of any scale; self-comparison is
    exactly zero on every drift metric."""
    cur = np.asarray(current, np.float64).ravel()
    ref = np.asarray(reference, np.float64).ravel()
    c5, c25, c50, c75, c95 = np.percentile(cur, [5, 25, 50, 75, 95])
    r5, r25, r50, r75, r95 = np.percentile(ref, [5, 25, 50, 75, 95])
    ref_range = max(abs(r95 - r5), _EPS)
    m = StabilityMetrics(
        p5=float(c5),
        p95=float(c95),
        range=float(c95 - c5),
        apd_p5=abs(c5 - r5) / ref_range,
        apd_p95=abs(c95 - r95) / ref_range,
        srd=abs((c95 - c5) - (r95 - r5)) / ref_range,
        jsd=jensen_shannon(cur, ref, bins=bins),
        median_drift=abs(c50 - r50) / ref_range,
        iqr_drift=abs((c75 - c25) - (r75 - r25)) / ref_range,
        is_stable=False,
    )
    m.is_stable = is_stable_under(m, thresholds)
    return m


def adapter_samples(adapters: Pytree, *, cap: int = 65536) -> np.ndarray:
    """Deterministic 1-D f32 sample vector over an adapter pytree: every
    float leaf flattened in tree order, stride-subsampled to at most
    ``cap`` values (same stride for the same tree shape — two runs of the
    same config always sample the same positions, so the metrics compare
    like with like)."""
    import jax

    leaves = [
        np.asarray(x, np.float32).ravel()
        for x in jax.tree_util.tree_leaves(adapters)
        if hasattr(x, "dtype") and np.issubdtype(np.asarray(x).dtype, np.floating)
    ]
    if not leaves:
        return np.zeros((1,), np.float32)
    flat = np.concatenate(leaves)
    if flat.size > cap:
        stride = int(np.ceil(flat.size / cap))
        flat = flat[::stride]
    return flat
