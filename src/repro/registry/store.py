"""Versioned, content-addressed calibration artifact store.

Every calibration run is persisted as an immutable artifact under a
registry KEY — ``(cfg fingerprint, backend, drift/fault signature)`` —
with versions that only ever grow:

    <root>/<cfg_fp>/<backend>/<sig_key>/
        store/step_0000000001/         # CheckpointManager payload: the
        store/step_0000000002/         #   adapters + optimizer pytrees
        v0000001.json                  # metadata sidecar per version
        v0000001_samples.npy           # adapter sample vector (metrics)
        reference.json                 # the promoted stable reference

* the payload rides on ``checkpoint.CheckpointManager`` (atomic
  tmp-then-rename commits; version number == manager step), with
  retention effectively unbounded — a registry is an archive, not a
  rolling checkpoint window;
* the JSON sidecar carries everything needed WITHOUT loading arrays:
  the signature vector, the serialized ``CalibrationReport``, and the
  stability metrics measured against the reference at record time;
* the per-version sample vector (``registry/metrics.adapter_samples``)
  is stored beside the sidecar so drift checks against the reference
  never deserialize full adapter pytrees;
* ``reference.json`` is the key's single promoted version, replaced
  atomically (tmp + ``os.replace``) only when the promotion policy says
  the current reference went stale (``registry/policy.py``).

A version EXISTS once its sidecar is on disk — the sidecar is written
last, so a crash mid-record leaves at worst an orphaned payload that the
next record for the key overwrites.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager, as_manager
from repro.registry.metrics import (
    DEFAULT_THRESHOLDS,
    StabilityMetrics,
    StabilityThresholds,
    adapter_samples,
    stability_metrics,
)
from repro.registry.policy import PromotionDecision, PromotionPolicy

Pytree = Any

_SIG_DECIMALS = 6          # signature quantization for key identity
_FP_CHARS = 12             # hex chars kept from content hashes
_REFERENCE = "reference.json"
_STORE_DIR = "store"


def _short_hash(payload: str) -> str:
    return hashlib.sha1(payload.encode()).hexdigest()[:_FP_CHARS]


def cfg_fingerprint(cfg) -> str:
    """Content fingerprint of a model config: the ``repr`` of the frozen
    dataclass hashed — stable across processes (no salted ``hash()``),
    and any field change (adapter rank, rram constants, layer pattern)
    changes the fingerprint, so artifacts never cross config boundaries."""
    return _short_hash(repr(cfg))


def quantized_signature(signature) -> List[float]:
    """The signature vector rounded to registry key precision: runs whose
    drift states agree to ``1e-6`` share a key (and a reference chain);
    anything farther apart is a different key found only via
    nearest-reference lookup."""
    return [
        float(round(float(v), _SIG_DECIMALS))
        for v in np.asarray(signature, np.float64).ravel()
    ]


def signature_key(signature) -> str:
    return _short_hash(json.dumps(quantized_signature(signature)))


def _atomic_json(path: str, payload: Dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def _atomic_npy(path: str, arr: np.ndarray) -> None:
    tmp = path + ".tmp.npy"
    np.save(tmp, arr)
    os.replace(tmp, path)


@dataclasses.dataclass(frozen=True)
class RegistryKey:
    """One registry key: the identity an artifact is filed under."""

    cfg_fp: str
    backend: str
    sig_key: str
    signature: tuple  # quantized signature values

    @property
    def name(self) -> str:
        return f"{self.cfg_fp}/{self.backend}/{self.sig_key}"


@dataclasses.dataclass(frozen=True)
class ArtifactRecord:
    """One immutable recorded calibration (key + version + sidecar)."""

    key: RegistryKey
    version: int
    signature: np.ndarray
    meta: Dict
    promoted: bool

    @property
    def name(self) -> str:
        return f"{self.key.name}@v{self.version}"


class CalibrationRegistry:
    """Fleet-wide archive of versioned calibration artifacts. See module
    docstring for the on-disk layout and ``registry/warmstart.py`` for
    the nearest-stable-reference lookup built on top."""

    def __init__(
        self,
        root: str,
        *,
        thresholds: StabilityThresholds = DEFAULT_THRESHOLDS,
        policy: Optional[PromotionPolicy] = None,
        sample_cap: int = 65536,
    ):
        self.root = str(root)
        self.thresholds = thresholds
        self.policy = policy if policy is not None else PromotionPolicy()
        self.sample_cap = int(sample_cap)
        os.makedirs(self.root, exist_ok=True)

    # -- keys ----------------------------------------------------------------

    def key_for(self, cfg, backend: str, signature) -> RegistryKey:
        return RegistryKey(
            cfg_fp=cfg_fingerprint(cfg),
            backend=str(backend),
            sig_key=signature_key(signature),
            signature=tuple(quantized_signature(signature)),
        )

    def _key_dir(self, key: RegistryKey) -> str:
        return os.path.join(self.root, key.cfg_fp, key.backend, key.sig_key)

    def _manager(self, key: RegistryKey) -> CheckpointManager:
        # a registry key archives every version — retention is unbounded,
        # unlike the rolling keep=3 of lifecycle snapshots
        return as_manager(
            os.path.join(self._key_dir(key), _STORE_DIR), keep=10 ** 9
        )

    # -- introspection -------------------------------------------------------

    def versions(self, key: RegistryKey) -> List[int]:
        """All recorded versions under ``key``, ascending (a version
        exists iff its metadata sidecar does)."""
        d = self._key_dir(key)
        if not os.path.isdir(d):
            return []
        out = []
        for name in os.listdir(d):
            if name.startswith("v") and name.endswith(".json"):
                try:
                    out.append(int(name[1:-len(".json")]))
                except ValueError:
                    continue
        return sorted(out)

    def artifact(self, key: RegistryKey, version: int) -> ArtifactRecord:
        meta = self._read_meta(key, version)
        ref = self._read_reference(key)
        return ArtifactRecord(
            key=key, version=version,
            signature=np.asarray(meta["signature"], np.float64),
            meta=meta, promoted=(ref == version),
        )

    def _meta_path(self, key: RegistryKey, version: int) -> str:
        return os.path.join(self._key_dir(key), f"v{version:07d}.json")

    def _samples_path(self, key: RegistryKey, version: int) -> str:
        return os.path.join(self._key_dir(key), f"v{version:07d}_samples.npy")

    def _read_meta(self, key: RegistryKey, version: int) -> Dict:
        with open(self._meta_path(key, version)) as f:
            return json.load(f)

    def _read_reference(self, key: RegistryKey) -> Optional[int]:
        path = os.path.join(self._key_dir(key), _REFERENCE)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(json.load(f)["version"])

    def reference(self, key: RegistryKey) -> Optional[ArtifactRecord]:
        """The promoted stable reference for ``key`` (None: virgin key)."""
        version = self._read_reference(key)
        if version is None:
            return None
        return self.artifact(key, version)

    def references(self, cfg, backend: str) -> List[ArtifactRecord]:
        """Every key's promoted reference under ``(cfg, backend)``,
        deterministically ordered by signature key — the candidate set
        for nearest-reference warm-start lookup."""
        base = os.path.join(self.root, cfg_fingerprint(cfg), str(backend))
        if not os.path.isdir(base):
            return []
        out: List[ArtifactRecord] = []
        for sig_key in sorted(os.listdir(base)):
            ref_path = os.path.join(base, sig_key, _REFERENCE)
            if not os.path.exists(ref_path):
                continue
            with open(ref_path) as f:
                version = int(json.load(f)["version"])
            meta_path = os.path.join(
                base, sig_key, f"v{version:07d}.json"
            )
            if not os.path.exists(meta_path):
                continue
            with open(meta_path) as f:
                meta = json.load(f)
            key = RegistryKey(
                cfg_fp=cfg_fingerprint(cfg), backend=str(backend),
                sig_key=sig_key, signature=tuple(meta["signature"]),
            )
            out.append(ArtifactRecord(
                key=key, version=version,
                signature=np.asarray(meta["signature"], np.float64),
                meta=meta, promoted=True,
            ))
        return out

    def samples(self, record: ArtifactRecord) -> Optional[np.ndarray]:
        path = self._samples_path(record.key, record.version)
        if not os.path.exists(path):
            return None
        return np.load(path)

    # -- record --------------------------------------------------------------

    def record(
        self,
        cfg,
        backend: str,
        signature,
        *,
        adapters: Pytree,
        opt_state: Pytree,
        report=None,
        extra_meta: Optional[Dict] = None,
    ) -> ArtifactRecord:
        """Persist one calibration run as the key's next version, measure
        its stability against the current reference, and (per the
        promotion policy) atomically promote it. Returns the record,
        whose ``meta['metrics']`` carries the measured drift and
        ``meta['promotion']`` the decision."""
        key = self.key_for(cfg, backend, signature)
        os.makedirs(self._key_dir(key), exist_ok=True)
        existing = self.versions(key)
        version = (existing[-1] + 1) if existing else 1

        samples = adapter_samples(adapters, cap=self.sample_cap)
        ref_version = self._read_reference(key)
        metrics: Optional[StabilityMetrics] = None
        if ref_version is not None:
            ref_samples = self.samples(
                ArtifactRecord(key, ref_version, np.zeros(0), {}, True)
            )
            if ref_samples is not None:
                metrics = stability_metrics(
                    samples, ref_samples, thresholds=self.thresholds
                )
        decision: PromotionDecision = self.policy.decide(
            has_reference=ref_version is not None, metrics=metrics
        )

        if report is not None and hasattr(report, "to_dict"):
            report = report.to_dict()
        meta = {
            "format": 1,
            "version": version,
            "cfg_fp": key.cfg_fp,
            "backend": key.backend,
            "signature": list(key.signature),
            "reference_version": ref_version,
            "report": report,
            "metrics": None if metrics is None else metrics.to_dict(),
            "promotion": {
                "promote": decision.promote, "reason": decision.reason
            },
            "thresholds": self.thresholds.to_dict(),
        }
        if extra_meta:
            meta.update(extra_meta)

        # payload first, samples second, sidecar LAST (a version exists
        # iff its sidecar does), promotion after the version is whole
        self._manager(key).save(
            version, {"adapters": adapters, "opt": opt_state}
        )
        _atomic_npy(self._samples_path(key, version), samples)
        _atomic_json(self._meta_path(key, version), meta)
        if decision.promote:
            _atomic_json(
                os.path.join(self._key_dir(key), _REFERENCE),
                {"version": version, "reason": decision.reason},
            )
        return ArtifactRecord(
            key=key, version=version,
            signature=np.asarray(key.signature, np.float64),
            meta=meta, promoted=decision.promote,
        )

    # -- load ----------------------------------------------------------------

    def load(self, record: ArtifactRecord, like: Dict[str, Pytree]) -> Dict:
        """Load a record's payload pytrees. ``like`` supplies structure
        and dtypes (typically ``{"adapters": dep.adapters, "opt":
        adamw_init(dep.adapters)}``); the arrays come back bitwise as
        recorded."""
        return self._manager(record.key).restore(record.version, like)
