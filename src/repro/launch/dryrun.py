import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, prove memory fit, and extract roofline
terms. MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun``.

The two lines above run before any jax import so the CPU host platform
exposes 512 placeholder devices; nothing here allocates device memory —
all inputs/params are ShapeDtypeStructs.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import deploy  # noqa: E402
from repro.configs import ARCH_IDS, get_arch, input_specs  # noqa: E402
from repro.configs.shapes import ArchSpec, ShapeSpec  # noqa: E402
from repro.core.calibrate import CalibState, make_calib_step  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch.roofline import Roofline, collective_bytes  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim.adam import AdamW  # noqa: E402
from repro.sharding import rules as sh  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts")

# Abstract deployment views (eval_shape) come from the lifecycle API so
# the compile planner and the live drivers build the same structures.
abstract_params = deploy.abstract_params


def _model_flops(cfg, arch, params_abs, shape: ShapeSpec, n_devices: int) -> float:
    """Useful-model-FLOPs per device: 2*N_active per token forward,
    6*N_active per token for the calibration step (teacher fwd + student
    fwd + adapter backward ~ 2N each)."""
    base = params_abs["base"]
    n_total = sum(x.size for x in jax.tree_util.tree_leaves(base))
    embed = base["embed"]["embedding"].size
    n_mat = n_total - embed
    if cfg.moe is not None:
        frac = T.active_param_fraction(cfg, params_abs)
        n_mat = n_mat * frac
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        per_tok = 6.0 * n_mat
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        per_tok = 2.0 * n_mat
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        per_tok = 2.0 * n_mat
    return per_tok * tokens / n_devices


def build_step(arch: ArchSpec, shape: ShapeSpec, mesh, *, smoke=False,
               cfg_override=None):
    """Returns (fn, args_abstract, in_shardings, out_shardings, params)."""
    cfg = cfg_override if cfg_override is not None else (
        arch.smoke if smoke else arch.full
    )
    dp = mesh_lib.dp_axes(mesh)
    tp = mesh_lib.tp_axis(mesh)
    params_abs = abstract_params(cfg)
    p_sh = sh.param_shardings(params_abs, mesh, dp=dp, tp=tp)
    batch_abs = input_specs(arch, shape, smoke=smoke)
    b_sh = sh.batch_shardings(batch_abs, mesh, dp=dp, tp=tp)

    if shape.kind == "train":
        opt = AdamW(lr=1e-3)
        step_fn = make_calib_step(cfg, opt)
        state_abs = deploy.abstract_calib_state(cfg, params_abs)
        opt_abs = state_abs.opt_state
        opt_sh = sh.tree_shardings(opt_abs, mesh, (), dp=dp, tp=tp)
        step_sh = sh.tree_shardings(
            jax.ShapeDtypeStruct((), jnp.int32), mesh, (), dp=dp, tp=tp
        )
        state_sh = CalibState(
            p_sh["base"], p_sh["base"], p_sh["adapters"], opt_sh, step_sh
        )
        return (
            step_fn,
            (state_abs, batch_abs),
            (state_sh, b_sh),
            (state_sh, None),
            params_abs,
        )

    # inference paths serve the MERGED adapters (Algorithm 2 line 12)
    merged_abs = deploy.abstract_serve_params(cfg, params_abs)["adapters"]
    m_sh = sh.tree_shardings(merged_abs, mesh, (), dp=dp, tp=tp)
    p_sh_serve = {"base": p_sh["base"], "adapters": m_sh}

    if shape.kind == "prefill":
        def prefill(params, batch):
            return T.forward(params, batch, cfg)
        return (
            prefill,
            ({"base": params_abs["base"], "adapters": merged_abs}, batch_abs),
            (p_sh_serve, b_sh),
            None,
            params_abs,
        )

    # decode
    max_len = shape.seq_len
    src = min(arch.enc_src_len or 4096, 4096)
    cache_abs = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, max_len, src_len=src)
    )
    c_sh = sh.cache_shardings(cache_abs, mesh, dp=dp, tp=tp)

    def decode(params, cache, tokens, pos):
        return T.decode_step(params, cache, tokens, pos, cfg)

    args_abs = (
        {"base": params_abs["base"], "adapters": merged_abs},
        cache_abs,
        batch_abs["tokens"],
        batch_abs["pos"],
    )
    tok_sh = sh.batch_shardings(batch_abs, mesh, dp=dp, tp=tp)
    in_sh = (p_sh_serve, c_sh, tok_sh["tokens"], tok_sh["pos"])
    out_sh = (None, c_sh)
    return decode, args_abs, in_sh, out_sh, params_abs


def _compile_once(arch, cfg, shape, mesh, *, smoke=False):
    """Lower + compile one variant; returns (compiled, params_abs)."""
    dp = mesh_lib.dp_axes(mesh)
    tp = mesh_lib.tp_axis(mesh)
    with mesh_lib.mesh_context(mesh), sh.logical_axes(dp, tp):
        fn, args_abs, in_sh, out_sh, params_abs = build_step(
            arch, shape, mesh, smoke=smoke, cfg_override=cfg
        )
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args_abs)
        compiled = lowered.compile()
    return compiled, params_abs


def _extract(compiled) -> Dict:
    cost = compiled.cost_analysis()
    # jax 0.4.x returns a one-element list of per-program dicts; >=0.5
    # returns the dict directly.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        peak = getattr(mem, "peak_memory_in_bytes", None)
        if not peak:
            peak = (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            )
    except Exception:
        peak = None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(v for k, v in coll.items() if k != "_counts")),
        "coll_breakdown": coll,
        "peak": peak,
        "hlo": hlo,
    }


def _depth_units(cfg) -> Tuple[int, int, int]:
    """(prologue, period, full_n_periods[, epilogue folded into reduce])."""
    p = cfg.scan_period
    pro = cfg.prologue_layers
    body = cfg.n_layers - pro
    n_full = body // p
    epi = body % p
    return pro, p, n_full, epi


def _reduced_cfg(cfg, n_periods: int):
    """Depth-reduced unrolled variant with identical per-period structure
    (prologue + n_periods*period + the full config's epilogue remainder)."""
    import dataclasses as _dc
    pro, p, _, epi = _depth_units(cfg)
    n_layers = pro + n_periods * p + epi
    enc = cfg.encoder_layers
    if enc:
        # scale the encoder with the decoder so the extrapolation unit is
        # "one enc layer + one dec layer"
        enc = max(1, round(enc * n_layers / cfg.n_layers))
    return _dc.replace(cfg, n_layers=n_layers, encoder_layers=enc, unroll=True)


def run_cell(
    arch_id: str, shape_name: str, *, multi_pod: bool, smoke: bool = False,
    keep_hlo: bool = False, roofline: bool = True,
) -> Tuple[Optional[Roofline], Optional[str]]:
    """One (arch, shape, mesh) cell.

    1. FULL config, scan-grouped layers: lower + compile — this is
       deliverable (e): proves sharding coherence + memory fit (peak
       memory from the real full-size artifact).
    2. (single-pod only, roofline=True) two depth-REDUCED unrolled
       variants: per-layer costs are affine in depth, so the full-depth
       FLOPs/bytes/collective-bytes are the exact affine extrapolation
       (lax.scan bodies are otherwise counted once by cost_analysis, not
       once per trip — see EXPERIMENTS.md §Roofline, Method).
    """
    arch = get_arch(arch_id)
    if shape_name in arch.skips:
        return None, f"SKIP {arch_id} {shape_name}: {arch.skips[shape_name]}"
    shape = arch.shapes[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = arch.smoke if smoke else arch.full

    t0 = time.time()
    compiled_full, params_abs = _compile_once(arch, cfg, shape, mesh, smoke=smoke)
    full_stats = _extract(compiled_full)
    t1 = time.time()
    msg = (
        f"OK   {arch_id} {shape_name} mesh={mesh_name} compile={t1-t0:.1f}s "
        f"peak_mem={(full_stats['peak'] or 0)/2**30:.2f}GiB"
    )
    if keep_hlo:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        with open(
            os.path.join(ARTIFACT_DIR, f"{arch_id}_{shape_name}_{mesh_name}.hlo"),
            "w",
        ) as f:
            f.write(full_stats["hlo"])

    if multi_pod or not roofline:
        # multi-pod cells prove the "pod" axis shards; the roofline table
        # is single-pod only (assignment spec).
        return None, msg

    pro, p, n_full, epi = _depth_units(cfg)
    n1, n2 = 1, 2
    del compiled_full
    c1, _ = _compile_once(arch, _reduced_cfg(cfg, n1), shape, mesh, smoke=smoke)
    s1 = _extract(c1)
    del c1
    c2, _ = _compile_once(arch, _reduced_cfg(cfg, n2), shape, mesh, smoke=smoke)
    s2 = _extract(c2)
    del c2

    def extrap(k):
        slope = (s2[k] - s1[k]) / (n2 - n1)
        return s1[k] + slope * (n_full - n1)

    coll_bd = {
        kind: (
            s1["coll_breakdown"][kind]
            + (s2["coll_breakdown"][kind] - s1["coll_breakdown"][kind])
            * (n_full - n1) / (n2 - n1)
        )
        for kind in s1["coll_breakdown"]
        if kind != "_counts"
    }
    rl = Roofline(
        arch=arch_id,
        shape=shape_name,
        mesh=mesh_name,
        flops=extrap("flops"),
        bytes_accessed=extrap("bytes"),
        coll_bytes=extrap("coll"),
        coll_breakdown=coll_bd,
        peak_memory=full_stats["peak"],
        model_flops=_model_flops(cfg, arch, params_abs, shape, mesh.size),
    )
    return rl, msg + " | " + rl.summary()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--smoke", action="store_true", help="use smoke configs")
    ap.add_argument("--out", default=None, help="write roofline JSON here")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    rows, failures = [], []
    for arch_id in archs:
        arch = get_arch(arch_id)
        shape_names = (
            list(arch.shapes) + list(arch.skips)
            if args.shape == "all"
            else [args.shape]
        )
        for shape_name in shape_names:
            for multi_pod in meshes:
                try:
                    rl, msg = run_cell(
                        arch_id, shape_name, multi_pod=multi_pod,
                        smoke=args.smoke, keep_hlo=args.keep_hlo,
                    )
                    print(msg, flush=True)
                    if rl is not None:
                        rows.append(rl)
                except Exception as e:  # a failure here is a bug in our system
                    failures.append((arch_id, shape_name, multi_pod, repr(e)))
                    print(
                        f"FAIL {arch_id} {shape_name} multi_pod={multi_pod}: {e}",
                        flush=True,
                    )
                    traceback.print_exc()
                if shape_name in arch.skips:
                    break  # skip message printed once, not per mesh
    if args.out:
        from repro.launch.roofline import save_rooflines
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        save_rooflines(rows, args.out)
        print(f"wrote {len(rows)} rooflines to {args.out}")
    print(f"\n{len(rows)} cells OK, {len(failures)} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
