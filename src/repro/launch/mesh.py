"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work, and for smoke
tests/benches to keep seeing exactly 1 CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions: ``jax.set_mesh``
    where it exists (>= 0.6), else the legacy ``Mesh`` context manager
    (0.4.x global mesh) — both make the mesh visible to
    ``with_sharding_constraint`` / shard_hint inside jitted bodies."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = 16x16 = 256 chips, axes
    ("data", "model"); the multi-pod mesh adds a leading "pod" axis over
    2 pods = 512 chips (DCN between pods, ICI within)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a production mesh (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def make_elastic_mesh(
    n_failed_hosts: int = 0, *, multi_pod: bool = False,
    base_mesh: Optional[Mesh] = None,
):
    """Degraded mesh after losing ``n_failed_hosts`` hosts: shrink the
    data axis (model axis untouched so param sharding is stable) —
    checkpoint/manager.py reshards state onto this mesh on restart, and
    ``ServeEngine.remesh`` replays in-flight slots onto it.

    With ``base_mesh`` the degraded mesh reuses the SURVIVING devices of
    that mesh (each data-axis row is one host): the trailing
    ``n_failed_hosts`` rows drop, the model axis keeps its exact device
    order. Without it, the production 16x16 (or 32x16 multi-pod) shape
    is rebuilt from the default device list."""
    if base_mesh is not None:
        names = base_mesh.axis_names
        if "data" not in names:
            raise ValueError(f"base_mesh has no 'data' axis: {names}")
        devs = np.asarray(base_mesh.devices)
        rows = devs.shape[names.index("data")] - n_failed_hosts
        if rows < 1:
            raise ValueError("no capacity left")
        idx = [slice(None)] * devs.ndim
        idx[names.index("data")] = slice(0, rows)
        return Mesh(devs[tuple(idx)], names)
    rows = (32 if multi_pod else 16) - n_failed_hosts
    if rows < 1:
        raise ValueError("no capacity left")
    return jax.make_mesh((rows, 16), ("data", "model"))


def make_host_mesh(shape: Tuple[int, ...] = (1, 8), axes=("data", "model")):
    """Small explicit mesh over the first ``prod(shape)`` local devices —
    the forced-CPU-device test/bench entry point (set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    importing jax)."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)
