"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work, and for smoke
tests/benches to keep seeing exactly 1 CPU device.
"""
from __future__ import annotations

from typing import Tuple

import jax


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions: ``jax.set_mesh``
    where it exists (>= 0.6), else the legacy ``Mesh`` context manager
    (0.4.x global mesh) — both make the mesh visible to
    ``with_sharding_constraint`` / shard_hint inside jitted bodies."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: one pod = 16x16 = 256 chips, axes
    ("data", "model"); the multi-pod mesh adds a leading "pod" axis over
    2 pods = 512 chips (DCN between pods, ICI within)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a production mesh (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def make_elastic_mesh(n_failed_hosts: int = 0, *, multi_pod: bool = False):
    """Degraded mesh after losing ``n_failed_hosts`` 16-chip hosts: shrink
    the data axis (model axis untouched so param sharding is stable) —
    checkpoint/manager.py reshards state onto this mesh on restart."""
    rows = (32 if multi_pod else 16) - n_failed_hosts
    if rows < 1:
        raise ValueError("no capacity left")
    return jax.make_mesh((rows, 16), ("data", "model"))
