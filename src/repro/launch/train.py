"""Calibration-training driver.

Runs the paper's DoRA feature-calibration as a production training job:
deterministic data, sharded calib_step under a mesh, periodic async
checkpoints, preemption-safe shutdown, straggler telemetry, and
restart/elastic-resume.

CPU-scale usage (CI / this container):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --smoke --steps 20 --batch 4 --seq 64

On a real pod the same driver runs with --mesh single|multi and the full
config; the step function is identical (it is the one the dry-run lowers).
"""
from __future__ import annotations

import argparse
import hashlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import deploy
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.core.calibrate import CalibState, make_calib_step
from repro.data.pipeline import DataConfig, global_batch_at_step
from repro.launch import mesh as mesh_lib
from repro.optim.adam import AdamW
from repro.runtime.fault import PreemptionGuard, StepTimer, StragglerDetector
from repro.sharding import rules as sh


def build_state(cfg, seed: int = 0, *, substrate_mode: str = "dequant") -> CalibState:
    """DEPRECATED shim: the deployment (programming event + calib state)
    is owned by ``repro.deploy.Deployment``; use ``dep.calib_state()``."""
    backend = "dequant" if substrate_mode == "dequant" else "codes"
    return deploy.Deployment.program(cfg, seed, backend=backend).calib_state()


def data_config(cfg, *, batch: int, seq: int, samples: int = 10) -> DataConfig:
    return DataConfig(
        vocab=cfg.vocab,
        seq_len=seq,
        global_batch=batch,
        n_calibration_samples=samples,
        enc_src_len=seq if cfg.encoder_layers else 0,
        d_model=cfg.d_model if (cfg.encoder_layers or cfg.vision_tokens) else 0,
        vision_tokens=cfg.vision_tokens,
    )


def train(
    arch_name: str,
    *,
    smoke: bool = False,
    steps: int = 50,
    batch: int = 4,
    seq: int = 64,
    lr: float = 1e-3,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    use_mesh: Optional[str] = None,  # None | 'single' | 'multi'
    resume: bool = True,
    seed: int = 0,
    log_every: int = 10,
    # Cache teacher features once per distinct calibration batch
    # (Algorithm 1 line 3; §Perf H-9: -29% FLOPs, -17% bytes per step).
    cached_teacher: bool = False,
    # Substrate representation of the programmed student: "dequant"
    # (drifted floats, today's fast path) or "codes" (resident uint8
    # CrossbarWeight leaves). Calibration always EXECUTES codes via the
    # differentiable 'dequant' backend — gradients flow to the adapters
    # while the codes stay frozen; serving can then flip the same
    # deployment to the fused 'codes' backend.
    backend: str = "dequant",
) -> Dict:
    arch = get_arch(arch_name)
    cfg = arch.smoke if smoke else arch.full
    opt = AdamW(lr=lr)
    use_cached = bool(cached_teacher)
    if use_cached:
        from repro.core.calibrate import make_cached_calib_step, teacher_features
        step_fn = make_cached_calib_step(cfg, opt)
    else:
        step_fn = make_calib_step(cfg, opt)
    dcfg = data_config(cfg, batch=batch, seq=seq)

    mesh = None
    if use_mesh:
        mesh = mesh_lib.make_production_mesh(multi_pod=use_mesh == "multi")
        dp, tp = mesh_lib.dp_axes(mesh), mesh_lib.tp_axis(mesh)

    dep = deploy.Deployment.program(cfg, seed, backend=backend)
    state = dep.calib_state()
    print(
        f"deployment: sram_bytes={dep.sram_bytes()} "
        f"({dep.calibrated_fraction():.2%} of params calibrated) "
        f"rram_bytes={dep.rram_bytes()} backend={backend}"
    )
    manager = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    start_step = 0
    if manager and resume and manager.latest_step() is not None:
        start_step = manager.latest_step()
        restored = manager.restore(
            start_step,
            {"adapters": state.adapters, "opt": state.opt_state},
        )
        state = CalibState(
            state.teacher_base, state.student_base,
            restored["adapters"], restored["opt"],
            jnp.asarray(start_step, jnp.int32),
        )
        dep.adopt(state)
        print(f"resumed from step {start_step}")

    import contextlib

    if mesh is not None:
        ctx = mesh_lib.mesh_context(mesh)
        hint_ctx = sh.logical_axes(dp, tp)
    else:
        ctx = contextlib.nullcontext()
        hint_ctx = contextlib.nullcontext()
    # codes-resident student: execute through the differentiable dequant
    # backend (the fused kernel is inference-shaped; AD needs the jnp path).
    if backend != "dequant":
        from repro import substrate
        backend_ctx = substrate.use_backend("dequant")
    else:
        backend_ctx = contextlib.nullcontext()

    # NOTE: no donation — teacher and student share digital-peripheral
    # buffers (norms/embeddings pass through program_model unchanged), and
    # XLA rejects donating the same buffer twice.
    jit_step = jax.jit(step_fn)
    detector = StragglerDetector()
    history = []
    feats_cache = {}
    with ctx, hint_ctx, backend_ctx, PreemptionGuard() as guard:
        for step in range(start_step, steps):
            np_batch = global_batch_at_step(dcfg, step)
            batch_dev = {
                k: jnp.asarray(
                    v, jnp.bfloat16 if v.dtype == np.float32 else None
                )
                for k, v in np_batch.items()
            }
            with StepTimer() as t:
                if use_cached:
                    # distinct calibration batches repeat (10-sample set):
                    # features keyed on batch CONTENT — tokens plus, for
                    # enc-dec/VLM configs, the encoder inputs / vision
                    # prefix — so a repeated batch reuses its trace and a
                    # changed encoder input can never alias a stale one
                    bkey = hashlib.sha1(
                        b"".join(
                            np.ascontiguousarray(np_batch[k]).tobytes()
                            for k in sorted(np_batch)
                        )
                    ).hexdigest()
                    if bkey not in feats_cache:
                        feats_cache[bkey] = teacher_features(
                            state.teacher_base, batch_dev, cfg
                        )
                    state, metrics = jit_step(state, feats_cache[bkey], batch_dev)
                else:
                    state, metrics = jit_step(state, batch_dev)
                loss = float(metrics["loss"])
            detector.record(step, t.elapsed)
            history.append(loss)
            if step % log_every == 0:
                print(f"step {step:5d} loss {loss:.6f} ({t.elapsed*1e3:.0f} ms)")
            if manager and (step + 1) % ckpt_every == 0:
                dep.adopt(state).snapshot(manager, blocking=False)
            if guard.should_stop:
                print("preemption requested: checkpoint + clean exit")
                if manager:
                    dep.adopt(state).snapshot(manager)
                break
    if manager:
        manager.wait()
    dep.adopt(state)
    return {
        "final_loss": history[-1] if history else None,
        "history": history,
        "straggler_reports": detector.reports,
        "state": state,
        "deployment": dep,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--backend", default="dequant", choices=["dequant", "codes"],
        help="substrate representation of the programmed student",
    )
    args = ap.parse_args()
    out = train(
        args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, use_mesh=args.mesh, seed=args.seed,
        backend=args.backend,
    )
    print(f"final loss: {out['final_loss']:.6f}")


if __name__ == "__main__":
    main()
