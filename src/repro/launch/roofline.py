"""Roofline term derivation from compiled dry-run artifacts.

TPU v5e hardware constants (per chip):
  peak bf16 compute 197 TFLOP/s, HBM BW 819 GB/s, ICI ~50 GB/s/link.

Terms (per device; cost_analysis of the SPMD-partitioned module is already
per-partition):
  compute_s    = HLO_FLOPs / peak
  memory_s     = HLO_bytes_accessed / hbm_bw
  collective_s = collective_bytes / ici_bw

collective_bytes is not in cost_analysis: we parse the post-SPMD HLO and
sum the *result-shape* bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (a ring-transfer proxy:
each device sends/receives ~result-size bytes per collective). Collectives
whose replica groups only span the "pod" axis would ride DCN, not ICI —
at 2 pods the proxy keeps them on the slower-of-the-two link constant,
which is conservative.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %all-gather.1 = bf16[2,4096,128]{2,1,0} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s(" + "|".join(_COLLECTIVES) + r")\("
)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from post-SPMD HLO text."""
    totals: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        hit = None
        for kind in _COLLECTIVES:
            if f" {kind}(" in stripped and "=" in stripped:
                hit = kind
                break
        if hit is None:
            continue
        # result may be a tuple (variadic collectives)
        lhs = stripped.split("=", 1)[1]
        head = lhs.split(hit + "(", 1)[0]
        size = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(head)
        )
        totals[hit] += size
        counts[hit] += 1
    totals["_counts"] = counts  # type: ignore
    return totals


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float  # per device
    bytes_accessed: float  # per device
    coll_bytes: float  # per device
    coll_breakdown: Dict[str, int]
    peak_memory: Optional[float]  # per device, bytes
    model_flops: float  # useful 6ND-style flops per device
    compile_ok: bool = True

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three overlapping terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak: useful model flops / (step_time * peak)."""
        t = self.step_time_s
        return self.model_flops / (t * PEAK_FLOPS) if t else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_breakdown": {
                k: v for k, v in self.coll_breakdown.items() if k != "_counts"
            },
            "coll_counts": self.coll_breakdown.get("_counts", {}),
            "peak_memory_per_dev": self.peak_memory,
            "model_flops_per_dev": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }

    def summary(self) -> str:
        return (
            f"{self.arch:24s} {self.shape:12s} {self.mesh:10s} "
            f"compute={self.compute_s*1e3:9.3f}ms mem={self.memory_s*1e3:9.3f}ms "
            f"coll={self.collective_s*1e3:9.3f}ms -> {self.bottleneck:10s} "
            f"useful={self.useful_flop_ratio:6.1%} roofline={self.roofline_fraction:6.1%}"
        )


def save_rooflines(rows, path):
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=1)
