"""Batched serving driver: prefill + decode with the calibrated student.

Demonstrates the deployment story of the paper: the RRAM base is frozen
(and drifted); accuracy comes from the DoRA side-cars that were calibrated
in SRAM. ``merge_magnitude`` (Algorithm 2 line 12) folds the DoRA column
norms once at load time so each decode matmul pays only the low-rank
epilogue.

The ``--backend`` flag selects the substrate execution backend
(repro/substrate): ``dequant`` (float read-back fast path, the default),
``codes`` (uint8 codes resident in HBM, fused Pallas kernel) or
``codes_adc`` (ADC-faithful fidelity path). Under ``codes``/``codes_adc``
the reported ``rram_bytes`` is a measurement of the resident code arrays,
not an estimate.

CPU-scale usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 16 --gen 8 [--backend codes]
"""
from __future__ import annotations

import argparse
import contextlib
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import substrate
from repro.configs import get_arch
from repro.core.calibrate import program_model, rram_bytes
from repro.models import transformer as T

BACKENDS = ("dequant", "codes", "codes_adc")


def load_student(cfg, seed: int = 0, adapters=None, *, backend: str = "dequant") -> Dict:
    """Init a teacher, program it onto RRAM, attach (given or fresh)
    adapters with the DoRA magnitudes merged for serving (Algorithm 2
    line 12 — no per-step norm recompute; §Perf H-6).

    ``backend='dequant'`` programs the deployment as drifted floats
    (today's fast path); ``'codes'``/``'codes_adc'`` keep the uint8
    conductance codes resident (same programming event, same keys)."""
    from repro.core.calibrate import merge_adapters_for_serve

    mode = "dequant" if backend == "dequant" else "codes"
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    student = program_model(
        params["base"], cfg.rram, jax.random.PRNGKey(seed + 1), mode=mode
    )
    merged = merge_adapters_for_serve(student, adapters or params["adapters"])
    return {"base": student, "adapters": merged}


def backend_scope(backend: str, cfg=None):
    """Context manager binding the substrate backend for trace time.
    Passing the model config plumbs its RramConfig into the ADC-faithful
    backend (code_max/adc_bits must match the programmed deployment)."""
    if backend == "dequant":
        return contextlib.nullcontext()
    if backend == "codes_adc" and cfg is not None:
        return substrate.use_backend(
            backend, code_max=cfg.rram.code_max, adc_bits=cfg.rram.adc_bits
        )
    return substrate.use_backend(backend)


def prefill_and_cache(params, tokens, cfg, max_len: int, enc_embeds=None):
    """Run the prompt through the model step-by-step to build the cache.

    (A fused full-sequence prefill that scatters into the cache is the
    perf path on TPU; the loop keeps serving logic simple on CPU and is
    identical in semantics.)
    """
    b, s = tokens.shape
    src_len = enc_embeds.shape[1] if enc_embeds is not None else 0
    cache = T.init_cache(cfg, b, max_len, src_len=src_len)
    if cfg.encoder_layers:
        cache["enc_out"] = T.encode(
            params["base"], params["adapters"], enc_embeds, cfg
        )
    logits = None
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    for i in range(s):
        logits, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
    return logits, cache


def generate(
    params, prompt: jax.Array, cfg, *, gen_len: int = 16,
    temperature: float = 0.0, enc_embeds=None, key=None,
) -> Tuple[np.ndarray, float]:
    b, s = prompt.shape
    max_len = s + gen_len
    logits, cache = prefill_and_cache(params, prompt, cfg, max_len, enc_embeds)
    out = []
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(gen_len):
        out.append(np.asarray(tok))
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        if temperature > 0 and key is not None:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.perf_counter() - t0
    return np.concatenate(out, axis=1), dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--backend", default="dequant", choices=BACKENDS,
        help="substrate execution backend (see repro/substrate)",
    )
    args = ap.parse_args()
    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.full
    params = load_student(cfg, args.seed, backend=args.backend)
    kind = "measured resident" if args.backend != "dequant" else "estimated"
    print(f"rram_bytes: {rram_bytes(params['base'])} ({kind})")
    key = jax.random.PRNGKey(args.seed)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    enc = None
    if cfg.encoder_layers:
        enc = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16
        )
    with backend_scope(args.backend, cfg):
        toks, dt = generate(params, prompt, cfg, gen_len=args.gen, enc_embeds=enc)
    tps = args.batch * args.gen / dt
    print(f"backend={args.backend} generated {toks.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s)")
    print(toks[:2])


if __name__ == "__main__":
    main()
