"""Batched serving driver: prefill + decode with the calibrated student.

Demonstrates the deployment story of the paper: the RRAM base is frozen
(and drifted); accuracy comes from the DoRA side-cars that were calibrated
in SRAM. ``merge_magnitude`` (Algorithm 2 line 12) folds the DoRA column
norms once at load time so each decode matmul pays only the low-rank
epilogue.

CPU-scale usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.calibrate import program_model
from repro.models import transformer as T


def load_student(cfg, seed: int = 0, adapters=None) -> Dict:
    """Init a teacher, program it onto RRAM, attach (given or fresh)
    adapters with the DoRA magnitudes merged for serving (Algorithm 2
    line 12 — no per-step norm recompute; §Perf H-6)."""
    from repro.core.calibrate import merge_adapters_for_serve

    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    student = program_model(params["base"], cfg.rram, jax.random.PRNGKey(seed + 1))
    merged = merge_adapters_for_serve(student, adapters or params["adapters"])
    return {"base": student, "adapters": merged}


def prefill_and_cache(params, tokens, cfg, max_len: int, enc_embeds=None):
    """Run the prompt through the model step-by-step to build the cache.

    (A fused full-sequence prefill that scatters into the cache is the
    perf path on TPU; the loop keeps serving logic simple on CPU and is
    identical in semantics.)
    """
    b, s = tokens.shape
    src_len = enc_embeds.shape[1] if enc_embeds is not None else 0
    cache = T.init_cache(cfg, b, max_len, src_len=src_len)
    if cfg.encoder_layers:
        cache["enc_out"] = T.encode(
            params["base"], params["adapters"], enc_embeds, cfg
        )
    logits = None
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    for i in range(s):
        logits, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
    return logits, cache


def generate(
    params, prompt: jax.Array, cfg, *, gen_len: int = 16,
    temperature: float = 0.0, enc_embeds=None, key=None,
) -> Tuple[np.ndarray, float]:
    b, s = prompt.shape
    max_len = s + gen_len
    logits, cache = prefill_and_cache(params, prompt, cfg, max_len, enc_embeds)
    out = []
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(gen_len):
        out.append(np.asarray(tok))
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        if temperature > 0 and key is not None:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.perf_counter() - t0
    return np.concatenate(out, axis=1), dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.full
    params = load_student(cfg, args.seed)
    key = jax.random.PRNGKey(args.seed)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    enc = None
    if cfg.encoder_layers:
        enc = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16
        )
    toks, dt = generate(params, prompt, cfg, gen_len=args.gen, enc_embeds=enc)
    tps = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(toks[:2])


if __name__ == "__main__":
    main()
