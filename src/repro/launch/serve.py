"""Batched serving driver over the deployment lifecycle API.

The deployment story of the paper — program once, drift in the field,
calibrate the SRAM side-cars, serve — is owned by
``repro.deploy.Deployment``; this driver just parses flags, programs a
deployment and serves it. ``load_student`` / ``backend_scope`` /
``prefill_and_cache`` / ``generate`` remain as thin deprecation shims
over ``repro.deploy`` for callers of the old free-function API.

The ``--backend`` flag selects the substrate execution backend
(repro/substrate): ``dequant`` (float read-back fast path, the default),
``codes`` (uint8 codes resident in HBM, fused Pallas kernel) or
``codes_adc`` (ADC-faithful fidelity path). Under ``codes``/``codes_adc``
the reported ``rram_bytes`` is a measurement of the resident code arrays,
not an estimate.

CPU-scale usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 16 --gen 8 [--backend codes]

Tensor-parallel serving (codes backend only) shards the prepared tree
over a ("data", "model") mesh; on CPU, force the device count BEFORE
python starts:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --backend codes --mesh-model 4
"""
from __future__ import annotations

import argparse
from typing import Dict

import jax
import jax.numpy as jnp

from repro import deploy
from repro.configs import get_arch

# Re-exported lifecycle pieces (deprecated import path; use repro.deploy).
BACKENDS = deploy.BACKENDS
backend_scope = deploy.backend_scope
prefill_and_cache = deploy.prefill_and_cache
generate = deploy.generate


def load_student(cfg, seed: int = 0, adapters=None, *, backend: str = "dequant") -> Dict:
    """DEPRECATED shim over ``repro.deploy.Deployment``: program a
    deployment and return the LEGACY serve-param layout (raw per-leaf
    base + adapters merged, Algorithm 2 line 12). Same seeding as always
    — ``Deployment.program(cfg, seed)`` programs the identical deployment
    (bitwise-identical codes). ``Deployment.serve().params`` is the
    modern path and, under the codes backend, holds the PREPARED
    (padded/fused) serving tree instead — this shim keeps the raw layout
    its remaining callers index into."""
    from repro.core.calibrate import merge_adapters_for_serve

    dep = deploy.Deployment.program(cfg, seed, backend=backend)
    if adapters is not None:
        dep.adapters = adapters
    merged = merge_adapters_for_serve(dep.base, dep.adapters)
    return {"base": dep.base, "adapters": merged}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument(
        "--drift-hours", type=float, default=0.0,
        help="advance the drift clock this many hours before serving",
    )
    ap.add_argument(
        "--backend", default="dequant", choices=BACKENDS,
        help="substrate execution backend (see repro/substrate)",
    )
    ap.add_argument(
        "--mesh-model", type=int, default=0,
        help="tensor-parallel degree: shard serving over a (1, N) "
             "('data', 'model') mesh (codes backend only; needs >= N "
             "devices — on CPU set XLA_FLAGS device forcing first)",
    )
    args = ap.parse_args()
    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.full

    mesh = None
    if args.mesh_model > 1:
        from repro.launch.mesh import make_host_mesh

        if jax.device_count() < args.mesh_model:
            raise SystemExit(
                f"--mesh-model {args.mesh_model} needs that many devices; "
                f"only {jax.device_count()} visible (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N before launch)"
            )
        mesh = make_host_mesh((1, args.mesh_model))

    dep = deploy.Deployment.program(cfg, args.seed, backend=args.backend)
    if args.drift_hours > 0:
        dep.advance(args.drift_hours)
    session = dep.serve(mesh=mesh)
    print(session.describe())

    # independent streams for the prompt tokens and the encoder/vision
    # embeds — reusing one key correlated the draws
    key = jax.random.PRNGKey(args.seed)
    prompt_key, enc_key, patch_key = jax.random.split(key, 3)
    prompt = jax.random.randint(
        prompt_key, (args.batch, args.prompt_len), 0, cfg.vocab
    )
    enc = None
    if cfg.encoder_layers:
        enc = jax.random.normal(
            enc_key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16
        )
    patches = None
    if cfg.vision_tokens:
        patches = jax.random.normal(
            patch_key, (args.batch, cfg.vision_tokens, cfg.d_model),
            jnp.bfloat16,
        )
    toks, dt = session.generate(
        prompt, gen_len=args.gen, temperature=args.temperature,
        enc_embeds=enc, patch_embeds=patches,
    )
    # dt times exactly the decode steps; the first token per stream comes
    # from prefill, so decode tok/s counts gen - 1 tokens per stream
    decode_toks = args.batch * max(args.gen - 1, 0)
    tps = decode_toks / dt if dt > 0 else float("nan")
    print(f"backend={args.backend} generated {toks.shape} "
          f"(decode: {decode_toks} tok in {dt:.2f}s = {tps:.1f} tok/s)")
    print(toks[:2])


if __name__ == "__main__":
    main()
