"""Fleet maintenance driver: program N chips, run a drift-driven
recalibration timeline, report the economics.

The single-chip drivers (``launch/train.py``, ``launch/serve.py``) own
one ``Deployment``; this driver owns a ``repro.fleet.Fleet`` — batched
per-chip programming noise and heterogeneous drift clocks — and a
``RecalibrationScheduler`` that recalibrates only the chips whose drift
proxy crossed the threshold at each maintenance tick.

CPU-scale usage:
    PYTHONPATH=src python -m repro.launch.fleet --arch qwen3-1.7b --smoke \
        --chips 8 --ticks 3 --tick-hours 24 --threshold 0.015 \
        [--backend codes] [--hetero] [--snapshot /ckpt/fleet]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_arch
from repro.deploy import BACKENDS
from repro.fleet import Fleet, RecalibrationScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="dequant", choices=BACKENDS)
    ap.add_argument("--ticks", type=int, default=3,
                    help="maintenance ticks to simulate")
    ap.add_argument("--tick-hours", type=float, default=24.0,
                    help="field hours per tick (scaled per chip if --hetero)")
    ap.add_argument("--hetero", action="store_true",
                    help="chip i ages i+1 times faster (heterogeneous clocks)")
    ap.add_argument("--threshold", type=float, default=0.015,
                    help="drift-proxy threshold that triggers recalibration")
    ap.add_argument("--samples", type=int, default=10)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--snapshot", default=None,
                    help="checkpoint directory for the final fleet state")
    args = ap.parse_args()
    arch = get_arch(args.arch)
    cfg = arch.smoke if args.smoke else arch.full

    fleet = Fleet.program(
        cfg, args.seed, n_chips=args.chips, backend=args.backend
    )
    print(f"programmed fleet of {args.chips} ({args.backend}): "
          f"rram_bytes={fleet.rram_bytes()} sram_bytes={fleet.sram_bytes()}")

    sched = RecalibrationScheduler(
        fleet, threshold=args.threshold,
        calib_args={"batch_or_samples": args.samples, "steps": args.steps,
                    "lr": args.lr, "seq_len": args.seq_len},
    )
    hours = (
        [args.tick_hours * (i + 1) for i in range(args.chips)]
        if args.hetero else args.tick_hours
    )
    for t in range(args.ticks):
        rec = sched.tick(hours)
        print(f"tick {t}: proxy={np.round(rec.proxy, 4).tolist()} "
              f"recalibrated={rec.recalibrated or 'none'}"
              + (f" | {rec.report.summary()}" if rec.report else ""))

    report = sched.report()
    print(report.summary())
    print(report.to_json())

    if args.snapshot:
        step = fleet.snapshot(args.snapshot)
        print(f"fleet snapshot at step {step} -> {args.snapshot}")


if __name__ == "__main__":
    main()
