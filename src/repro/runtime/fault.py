"""Fault tolerance & scale runtime: preemption handling, straggler
detection, elastic re-meshing.

The training driver (launch/train.py) wires these together:

* ``PreemptionGuard`` — converts SIGTERM/SIGINT into a "checkpoint now,
  then exit cleanly" flag checked each step (TPU pods deliver maintenance
  preemptions as SIGTERM).
* ``StragglerDetector`` — per-step wall-time ring buffer with a robust
  z-score; at >1000 hosts slow-HBM or thermally-throttled chips show up
  as persistent step-time outliers long before they fail. The hook
  reports and (policy) requests a re-mesh excluding the slow host.
* ``ElasticPlan`` — given a failed-host count, produce the degraded mesh
  (launch/mesh.py) + the resharding restore recipe (checkpoint/manager).
  Because the data pipeline is stateless (data/pipeline.py) and drift is
  deterministic given the programming key (core/calibrate.py), recovery
  is exact: restore adapters+opt at step k, re-derive the student base,
  continue at step k with a smaller data axis.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import threading
import time
from typing import Callable, Deque, List, Optional

import numpy as np


class PreemptionGuard:
    """Flag-based graceful shutdown. Use as context manager around the
    training loop; ``should_stop`` flips on SIGTERM/SIGINT."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._signals = signals
        self._stop = threading.Event()
        self._prev = {}

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:  # non-main thread (tests)
                pass
        return self

    def _handler(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def request_stop(self):
        self._stop.set()

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    median: float
    mad: float
    z: float

    @property
    def is_straggler(self) -> bool:
        return self.z > 4.0


class StragglerDetector:
    """Robust (median/MAD) outlier detection over recent step times.

    A single flagged step is noise (GC pause, one slow collective); the
    re-mesh policy acts on ``persistent()`` — at least ``k`` of the most
    recent ``horizon`` steps flagged — which a one-off spike can never
    satisfy but a thermally-throttled host does within ``k`` steps."""

    def __init__(self, window: int = 64, min_samples: int = 16):
        self.times: Deque[float] = collections.deque(maxlen=window)
        self.min_samples = min_samples
        self.reports: List[StragglerReport] = []
        self._flags: Deque[bool] = collections.deque(maxlen=window)

    def record(self, step: int, step_time: float) -> Optional[StragglerReport]:
        self.times.append(step_time)
        if len(self.times) < self.min_samples:
            self._flags.append(False)
            return None
        arr = np.asarray(self.times)
        med = float(np.median(arr))
        mad = float(np.median(np.abs(arr - med))) + 1e-9
        z = 0.6745 * (step_time - med) / mad
        report = StragglerReport(step, step_time, med, mad, float(z))
        self._flags.append(report.is_straggler)
        if report.is_straggler:
            self.reports.append(report)
        return report

    def persistent(self, k: int = 3, horizon: int = 8) -> bool:
        """True when >= ``k`` of the last ``horizon`` recorded steps were
        flagged — the signal that justifies excluding the host."""
        recent = list(self._flags)[-horizon:]
        return sum(recent) >= k


@dataclasses.dataclass
class ElasticPlan:
    """Recipe for recovering onto a degraded mesh."""

    failed_hosts: int
    new_mesh_shape: tuple
    restore_step: int
    notes: str = ""

    @staticmethod
    def plan(
        failed_hosts: int, latest_step: Optional[int], *,
        rows: int = 16, cols: int = 16,
    ):
        """``rows``/``cols`` are the current ("data", "model") extents —
        the production 16x16 by default; serve engines pass their actual
        mesh shape. Only the data axis shrinks."""
        new_rows = rows - failed_hosts
        if new_rows < 1:
            raise RuntimeError("insufficient healthy capacity for re-mesh")
        return ElasticPlan(
            failed_hosts=failed_hosts,
            new_mesh_shape=(new_rows, cols),
            restore_step=latest_step or 0,
            notes=(
                "model axis preserved (param shardings stable); data axis "
                f"shrunk {rows}->{new_rows}; global batch kept — per-device "
                "batch grows, data pipeline replays deterministically"
            ),
        )


class StepTimer:
    """Context timer used by the train loop for the straggler detector."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0
        return False
