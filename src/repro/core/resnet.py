"""ResNet-CIFAR family for the paper-faithful reproduction (§IV).

The paper evaluates on ResNet-20/CIFAR-100 and ResNet-50/ImageNet-1K.
Neither dataset nor pretrained weights are available offline, so the
reproduction (EXPERIMENTS.md §Repro) trains the same ResNet-20 topology
from scratch as the "GPU teacher" on a procedurally generated image
classification task, then runs the paper's full protocol: drift
injection -> accuracy drop -> feature-based DoRA calibration vs LoRA vs
backprop, sweeping calibration-set size and rank r.

Architecture: standard CIFAR ResNet (He et al.): conv3x3(16) ->
3 stages x n blocks (16/32/64, stride 2 between stages) -> avgpool -> fc.
depth = 6n+2 (n=3 -> ResNet-20). BatchNorm runs in inference mode with
teacher statistics during calibration — the paper's "no BN updates"
property holds by construction (§III-B).

Every conv/fc weight is RRAM-resident (leaf name "w" — picked up by
core/calibrate.program_model); DoRA/LoRA side-cars attach per layer via
core/dora conv adapters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dora
from repro.core.dora import AdapterConfig


@dataclasses.dataclass(frozen=True)
class ResnetConfig:
    depth: int = 20  # 6n+2
    width: int = 16
    classes: int = 100
    image_size: int = 32
    adapter: AdapterConfig = AdapterConfig(rank=2, kind="dora")

    @property
    def n_blocks(self) -> int:
        assert (self.depth - 2) % 6 == 0
        return (self.depth - 2) // 6


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def block_stride(cfg: ResnetConfig, block_idx: int) -> int:
    """Stride is STRUCTURE, not a parameter: 2 at each stage boundary
    (except the first stage), 1 otherwise."""
    stage, b = divmod(block_idx, cfg.n_blocks)
    return 2 if (stage > 0 and b == 0) else 1


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * np.sqrt(2.0 / fan_in)
    return {"w": w.astype(jnp.float32)}


def _bn_init(c):
    return {
        "scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
        "mean": jnp.zeros((c,)), "var": jnp.ones((c,)),
    }


def init_resnet(key: jax.Array, cfg: ResnetConfig) -> Dict:
    keys = iter(jax.random.split(key, 200))
    base: Dict = {"stem": _conv_init(next(keys), 3, 3, 3, cfg.width)}
    base["stem_bn"] = _bn_init(cfg.width)
    widths = [cfg.width, cfg.width * 2, cfg.width * 4]
    blocks = []
    cin = cfg.width
    for stage, cout in enumerate(widths):
        for b in range(cfg.n_blocks):
            stride = block_stride(cfg, stage * cfg.n_blocks + b)
            blk = {
                "conv1": _conv_init(next(keys), 3, 3, cin, cout),
                "bn1": _bn_init(cout),
                "conv2": _conv_init(next(keys), 3, 3, cout, cout),
                "bn2": _bn_init(cout),
            }
            if stride != 1 or cin != cout:
                blk["proj"] = _conv_init(next(keys), 1, 1, cin, cout)
                blk["proj_bn"] = _bn_init(cout)
            blocks.append(blk)
            cin = cout
    base["blocks"] = blocks
    kfc = next(keys)
    base["fc"] = {
        "w": (jax.random.normal(kfc, (cin, cfg.classes))
              * (cin ** -0.5)).astype(jnp.float32)
    }
    return base


def init_adapters(key: jax.Array, base: Dict, cfg: ResnetConfig) -> Dict:
    """DoRA/LoRA side-cars mirroring every conv/fc weight."""
    acfg = cfg.adapter
    keys = iter(jax.random.split(key, 200))

    def conv_ad(w):
        kh, kw, cin, cout = w.shape
        return dora.init_conv_adapter(next(keys), kh, kw, cin, cout, acfg, w)

    ad: Dict = {"stem": conv_ad(base["stem"]["w"]), "blocks": []}
    for blk in base["blocks"]:
        abk = {
            "conv1": conv_ad(blk["conv1"]["w"]),
            "conv2": conv_ad(blk["conv2"]["w"]),
        }
        if "proj" in blk:
            abk["proj"] = conv_ad(blk["proj"]["w"])
        ad["blocks"].append(abk)
    d, c = base["fc"]["w"].shape
    ad["fc"] = dora.init_adapter(next(keys), d, c, acfg, w_base=base["fc"]["w"])
    return ad


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _bn(x, p, training: bool, momentum=0.9):
    if training:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_stats = (
            momentum * p["mean"] + (1 - momentum) * mean,
            momentum * p["var"] + (1 - momentum) * var,
        )
    else:
        mean, var = p["mean"], p["var"]
        new_stats = (p["mean"], p["var"])
    y = (x - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y, new_stats


def _conv(x, base, adapter, acfg, stride=1):
    if adapter:
        return dora.adapted_conv_forward(
            x, base["w"], adapter, acfg, stride=(stride, stride)
        )
    dn = jax.lax.conv_dimension_numbers(
        x.shape, base["w"].shape, ("NHWC", "HWIO", "NHWC")
    )
    return jax.lax.conv_general_dilated(
        x, base["w"].astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=dn,
    )


def forward(
    base: Dict,
    images: jax.Array,  # (B, H, W, 3)
    cfg: ResnetConfig,
    *,
    adapters: Optional[Dict] = None,
    training_bn: bool = False,
    collect_features: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Returns (logits, aux). aux = {"features": [...]} when collecting
    (one entry per conv output — the feature maps the paper aligns),
    and updated BN stats when training_bn."""
    acfg = cfg.adapter
    ad = adapters or {}
    feats: List[jax.Array] = []
    new_bn: Dict = {}

    h = _conv(images, base["stem"], ad.get("stem"), acfg)
    if collect_features:
        feats.append(h)
    h, new_bn["stem_bn"] = _bn(h, base["stem_bn"], training_bn)
    h = jax.nn.relu(h)
    new_bn["blocks"] = []
    for i, blk in enumerate(base["blocks"]):
        abk = ad["blocks"][i] if ad else {}
        stride = block_stride(cfg, i)
        y = _conv(h, blk["conv1"], abk.get("conv1"), acfg, stride)
        if collect_features:
            feats.append(y)
        y, s1 = _bn(y, blk["bn1"], training_bn)
        y = jax.nn.relu(y)
        y = _conv(y, blk["conv2"], abk.get("conv2"), acfg)
        if collect_features:
            feats.append(y)
        y, s2 = _bn(y, blk["bn2"], training_bn)
        sc = h
        stats = {"bn1": s1, "bn2": s2}
        if "proj" in blk:
            sc = _conv(h, blk["proj"], abk.get("proj"), acfg, stride)
            sc, sp = _bn(sc, blk["proj_bn"], training_bn)
            stats["proj_bn"] = sp
        h = jax.nn.relu(y + sc)
        new_bn["blocks"].append(stats)
    h = jnp.mean(h, axis=(1, 2))
    if ad.get("fc"):
        logits = dora.adapted_forward(h, base["fc"]["w"], ad["fc"], acfg)
    else:
        logits = h @ base["fc"]["w"]
    if collect_features:
        feats.append(logits)
    return logits, {"features": feats, "bn_stats": new_bn}


def apply_bn_stats(base: Dict, bn_stats: Dict, momentum=0.9) -> Dict:
    """Fold freshly computed batch statistics back into the params."""
    import copy
    out = copy.deepcopy(jax.tree_util.tree_map(lambda x: x, base))
    m, v = bn_stats["stem_bn"]
    out["stem_bn"]["mean"], out["stem_bn"]["var"] = m, v
    for i, stats in enumerate(bn_stats["blocks"]):
        for name, (mm, vv) in stats.items():
            out["blocks"][i][name]["mean"] = mm
            out["blocks"][i][name]["var"] = vv
    return out


# ---------------------------------------------------------------------------
# procedural dataset (offline stand-in for CIFAR; see module docstring)
# ---------------------------------------------------------------------------


def procedural_dataset(
    key: jax.Array, n: int, cfg: ResnetConfig, noise: float = 0.35,
    template_key: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Class = fixed random 8x8 low-res template upsampled to image_size;
    sample = template + jitter shift + Gaussian noise. Learnable by a
    small CNN yet non-trivial at the chosen noise level.

    Class TEMPLATES come from ``template_key`` (fixed default) so separate
    train/test draws share the same classes — only noise/shift/labels are
    resampled from ``key``."""
    k_t = template_key if template_key is not None else jax.random.PRNGKey(1234)
    k_y, k_n, k_s = jax.random.split(key, 3)
    temps = jax.random.normal(k_t, (cfg.classes, 8, 8, 3))
    temps = jax.image.resize(
        temps, (cfg.classes, cfg.image_size, cfg.image_size, 3), "nearest"
    )
    labels = jax.random.randint(k_y, (n,), 0, cfg.classes)
    imgs = temps[labels]
    shifts = jax.random.randint(k_s, (n, 2), -2, 3)

    def roll(img, s):
        return jnp.roll(img, (s[0], s[1]), axis=(0, 1))

    imgs = jax.vmap(roll)(imgs, shifts)
    imgs = imgs + noise * jax.random.normal(k_n, imgs.shape)
    return imgs.astype(jnp.float32), labels


def accuracy(base, images, labels, cfg, *, adapters=None, batch=256) -> float:
    hits = 0
    for i in range(0, images.shape[0], batch):
        logits, _ = forward(
            base, images[i : i + batch], cfg, adapters=adapters
        )
        hits += int(jnp.sum(jnp.argmax(logits, -1) == labels[i : i + batch]))
    return hits / images.shape[0]
