"""End-to-end experiment harness for the paper-faithful reproduction.

Implements the experimental protocol of §IV on the ResNet-CIFAR family +
procedural data (core/resnet.py): teacher training, drift injection,
feature-based DoRA/LoRA calibration (Algorithm 1+2), and the
backpropagation baseline the paper compares against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import calibrate, dora, resnet
from repro.core.dora import AdapterConfig
from repro.core.resnet import ResnetConfig
from repro.core.rram import RramConfig
from repro.optim.adam import AdamW, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# teacher training ("DNN trained on GPU", Algorithm 1 line 1)
# ---------------------------------------------------------------------------


def train_teacher(
    key: jax.Array,
    cfg: ResnetConfig,
    images: jax.Array,
    labels: jax.Array,
    *,
    epochs: int = 12,
    batch: int = 128,
    lr: float = 1e-3,
) -> Dict:
    base = resnet.init_resnet(key, cfg)
    opt = AdamW(lr=lr)
    # trainable: conv/fc weights + BN scale/bias (not running stats)
    opt_state = adamw_init(base)

    def loss_fn(params, x, y):
        logits, aux = resnet.forward(params, x, cfg, training_bn=True)
        ce = -jnp.mean(
            jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y]
        )
        return ce, aux["bn_stats"]

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, bn_stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, x, y
        )
        # freeze BN running stats against gradient updates
        grads = _zero_bn_stat_grads(grads)
        params, opt_state = adamw_update(grads, opt_state, params, opt)
        params = resnet.apply_bn_stats(params, bn_stats)
        return params, opt_state, loss

    n = images.shape[0]
    steps_per_epoch = max(1, n // batch)
    perm_key = key
    for e in range(epochs):
        perm_key, sub = jax.random.split(perm_key)
        perm = jax.random.permutation(sub, n)
        for s in range(steps_per_epoch):
            idx = perm[s * batch : (s + 1) * batch]
            base, opt_state, loss = step(base, opt_state, images[idx], labels[idx])
    return base


def _zero_bn_stat_grads(grads):
    def leaf(path, g):
        name = str(getattr(path[-1], "key", ""))
        if name in ("mean", "var"):
            return jnp.zeros_like(g)
        return g

    return jax.tree_util.tree_map_with_path(leaf, grads)


# ---------------------------------------------------------------------------
# drift injection (the "deployment" event)
# ---------------------------------------------------------------------------


def make_student(base: Dict, relative_drift: float, key: jax.Array) -> Dict:
    rcfg = RramConfig(relative_drift=relative_drift)
    return calibrate.program_model(base, rcfg, key)


# ---------------------------------------------------------------------------
# feature-based calibration (Algorithm 1 over the whole net, layer-local)
# ---------------------------------------------------------------------------


def calibration_loss_resnet(
    teacher: Dict, student: Dict, adapters: Dict, images: jax.Array,
    cfg: ResnetConfig,
) -> jax.Array:
    """Interleaved teacher/student walk: every student conv sees the
    TEACHER's input activation, so per-conv MSE gradients never cross
    layers — exactly Algorithm 1 as one jittable step (DESIGN.md §2)."""
    acfg = cfg.adapter

    def pair_conv(h_t, tb, sb, ad, stride=1):
        t_out = resnet._conv(h_t, tb, None, acfg, stride)
        s_out = resnet._conv(h_t, sb, ad, acfg, stride)
        d = (t_out - s_out).astype(jnp.float32)
        return t_out, jnp.mean(d * d)

    loss = jnp.zeros(())
    h, l0 = pair_conv(images, teacher["stem"], student["stem"], adapters["stem"])
    loss += l0
    h, _ = resnet._bn(h, teacher["stem_bn"], False)
    h = jax.nn.relu(h)
    for i, tblk in enumerate(teacher["blocks"]):
        sblk = student["blocks"][i]
        ablk = adapters["blocks"][i]
        stride = resnet.block_stride(cfg, i)
        y, l1 = pair_conv(h, tblk["conv1"], sblk["conv1"], ablk.get("conv1"), stride)
        loss += l1
        y, _ = resnet._bn(y, tblk["bn1"], False)
        y = jax.nn.relu(y)
        y2, l2 = pair_conv(y, tblk["conv2"], sblk["conv2"], ablk.get("conv2"))
        loss += l2
        y2, _ = resnet._bn(y2, tblk["bn2"], False)
        sc = h
        if "proj" in tblk:
            sc, lp = pair_conv(h, tblk["proj"], sblk["proj"], ablk.get("proj"), stride)
            loss += lp
            sc, _ = resnet._bn(sc, tblk["proj_bn"], False)
        h = jax.nn.relu(y2 + sc)
    feat = jnp.mean(h, axis=(1, 2))
    t_log = feat @ teacher["fc"]["w"]
    s_log = dora.adapted_forward(feat, student["fc"]["w"], adapters["fc"], acfg)
    d = (t_log - s_log).astype(jnp.float32)
    loss += jnp.mean(d * d)
    return loss


def feature_calibrate(
    teacher: Dict,
    student: Dict,
    adapters: Dict,
    images: jax.Array,
    cfg: ResnetConfig,
    *,
    epochs: int = 20,
    batch: int = 1,
    lr: float = 2e-3,
) -> Tuple[Dict, list]:
    """Paper setting: batch 1 over the calibration set, 20 epochs."""
    opt = AdamW(lr=lr)
    opt_state = adamw_init(adapters)

    @jax.jit
    def step(ad, opt_state, x):
        loss, grads = jax.value_and_grad(
            lambda a: calibration_loss_resnet(teacher, student, a, x, cfg)
        )(ad)
        ad, opt_state = adamw_update(grads, opt_state, ad, opt)
        return ad, opt_state, loss

    n = images.shape[0]
    bs = min(batch, n) if batch else n
    losses = []
    for e in range(epochs):
        total = 0.0
        for i in range(0, n, bs):
            adapters, opt_state, loss = step(adapters, opt_state, images[i : i + bs])
            total += float(loss)
        losses.append(total / max(1, n // bs))
    return adapters, losses


# ---------------------------------------------------------------------------
# backpropagation baseline (§II-B: full fine-tune with CE on the output)
# ---------------------------------------------------------------------------


def backprop_calibrate(
    student: Dict,
    images: jax.Array,
    labels: jax.Array,
    cfg: ResnetConfig,
    *,
    epochs: int = 20,
    batch: int = 1,
    lr: float = 1e-4,
) -> Tuple[Dict, int]:
    """Traditional retraining: ALL weights update (every step would be an
    RRAM write-and-verify pass in the field). Returns (params, n_rram_updates)."""
    opt = AdamW(lr=lr)
    opt_state = adamw_init(student)

    def loss_fn(params, x, y):
        logits, _ = resnet.forward(params, x, cfg)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y])

    @jax.jit
    def step(params, opt_state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        grads = _zero_bn_stat_grads(grads)
        params, opt_state = adamw_update(grads, opt_state, params, opt)
        return params, opt_state, loss

    n = images.shape[0]
    bs = min(batch, n) if batch else n
    updates = 0
    for e in range(epochs):
        for i in range(0, n, bs):
            student, opt_state, _ = step(
                student, opt_state, images[i : i + bs], labels[i : i + bs]
            )
            updates += 1
    return student, updates


# ---------------------------------------------------------------------------
# one full experiment cell
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ReproResult:
    teacher_acc: float
    drifted_acc: float
    calibrated_acc: float
    method: str
    samples: int
    rank: int
    drift: float
    trainable_fraction: float


def run_cell(
    *,
    seed: int = 0,
    cfg: Optional[ResnetConfig] = None,
    method: str = "dora",  # 'dora' | 'lora' | 'backprop'
    rank: int = 2,
    drift: float = 0.20,
    samples: int = 10,
    calib_epochs: int = 20,
    teacher: Optional[Dict] = None,
    data=None,
) -> ReproResult:
    cfg = cfg or ResnetConfig()
    if method in ("dora", "lora"):
        cfg = dataclasses.replace(
            cfg, adapter=AdapterConfig(rank=rank, kind=method)
        )
    key = jax.random.PRNGKey(seed)
    k_data, k_teacher, k_drift, k_ad, k_pick = jax.random.split(key, 5)
    if data is None:
        train_x, train_y = resnet.procedural_dataset(k_data, 2048, cfg)
        test_x, test_y = resnet.procedural_dataset(
            jax.random.fold_in(k_data, 7), 1024, cfg
        )
    else:
        train_x, train_y, test_x, test_y = data
    if teacher is None:
        teacher = train_teacher(k_teacher, cfg, train_x, train_y)
    teacher_acc = resnet.accuracy(teacher, test_x, test_y, cfg)
    student = make_student(teacher, drift, k_drift)
    drifted_acc = resnet.accuracy(student, test_x, test_y, cfg)

    pick = jax.random.permutation(k_pick, train_x.shape[0])[:samples]
    cal_x, cal_y = train_x[pick], train_y[pick]

    n_total = sum(
        x.size for x in jax.tree_util.tree_leaves(teacher)
    )
    if method == "backprop":
        student2, _ = backprop_calibrate(
            student, cal_x, cal_y, cfg, epochs=calib_epochs
        )
        acc = resnet.accuracy(student2, test_x, test_y, cfg)
        frac = 1.0
    else:
        adapters = resnet.init_adapters(k_ad, student, cfg)
        adapters, _ = feature_calibrate(
            teacher, student, adapters, cal_x, cfg, epochs=calib_epochs
        )
        acc = resnet.accuracy(student, test_x, test_y, cfg, adapters=adapters)
        n_ad = sum(x.size for x in jax.tree_util.tree_leaves(adapters))
        frac = n_ad / n_total
    return ReproResult(
        teacher_acc=teacher_acc,
        drifted_acc=drifted_acc,
        calibrated_acc=acc,
        method=method,
        samples=samples,
        rank=rank,
        drift=drift,
        trainable_fraction=frac,
    )
