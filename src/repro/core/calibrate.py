"""Calibration engine (paper Algorithm 1 + 2).

Two entry points:

* ``program_model`` — the "deployment" event: every RRAM-resident weight in
  a base pytree is programmed onto the simulated crossbar and drifted
  (deterministic per-leaf keys). Digital peripherals (norms, embeddings,
  conv kernels, SSM A/D, gates' biases, lambda) are left untouched.

* ``CalibrationLoop`` — the layer-wise feature-KD loop for the LM stacks
  (single jitted step over all layers; see
  ``transformer.feature_calibration_loss`` for why that is exactly
  Algorithm 1), with convergence thresholds and epoch caps per the paper.

The CNN reproduction (``core/resnet.py``) uses the literal per-layer loop
(`calibrate_layerwise`) to match the paper's procedure one-to-one.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import rram
from repro.core.rram import RramConfig
from repro.optim.adam import AdamW, adamw_init, adamw_update

Pytree = Any

# Leaf names that live in RRAM (weights that participate in MVMs).
RRAM_LEAF_NAMES = ("w", "gate_w", "up_w", "down_w")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _is_rram_leaf(path) -> bool:
    last = path[-1]
    name = getattr(last, "key", None)
    return name in RRAM_LEAF_NAMES


def program_model(
    base: Pytree,
    cfg: RramConfig,
    key: jax.Array,
    *,
    mode: str = "dequant",
) -> Pytree:
    """Program + drift every RRAM-resident leaf; returns the student base.

    Deterministic: each leaf's drift key is ``fold_in(key, hash(path))`` so
    re-programming with the same key reproduces the same deployment state
    (this is what makes on-restart recovery exact — see runtime/fault.py).

    ``mode`` selects the substrate representation of the returned tree:

    * ``"dequant"`` — drifted weights read back to the leaf's float dtype
      (today's training/CPU fast path).
    * ``"codes"`` — each RRAM leaf becomes a resident ``CrossbarWeight``
      (uint8 ``(G+, G-, scale)``), including the stacked expert /
      scan-group shapes. The SAME programming event: codes are bitwise
      identical across modes for identical keys, so backend parity holds
      to programming-quantization tolerance (the dequant path merely
      rounds the read-back to the float dtype).
    """
    if mode not in ("dequant", "codes"):
        raise ValueError(f"mode must be 'dequant' or 'codes', got {mode!r}")

    def leaf(path, x):
        if not _is_rram_leaf(path):
            return x
        # zlib.crc32 is stable across processes (builtin hash() is salted,
        # which would break exact recovery-on-restart).
        h = jnp.uint32(zlib.crc32(_path_str(path).encode()))
        return program_leaf(x, cfg, jax.random.fold_in(key, h), mode=mode)

    return jax.tree_util.tree_map_with_path(leaf, base)


def program_leaf(
    w: jax.Array, cfg: RramConfig, key: jax.Array, *, mode: str = "codes"
):
    """Program ONE RRAM leaf (its per-leaf key already folded in).

    This is the body ``program_model`` runs per leaf, split out so the
    fleet subsystem can ``jax.vmap`` it over per-chip keys — N chips'
    programming events land as ONE stacked draw, bitwise identical per
    chip to N sequential ``program_model`` calls with the same keys.
    """
    if w.ndim == 2:
        if mode == "codes":
            return rram.programmed_codes(w, cfg, key)
        return rram.drifted_weights(w, cfg, key, dtype=w.dtype)
    # stacked weights: (E, d, k) experts or (G, ..., d, k) scan bodies —
    # program each matrix; drift is i.i.d. so one vmapped call suffices.
    lead = w.shape[:-2]
    flat = w.reshape((-1,) + w.shape[-2:])
    keys = jax.random.split(key, flat.shape[0])
    if mode == "codes":
        out = jax.vmap(lambda m, kk: rram.programmed_codes(m, cfg, kk))(
            flat, keys
        )
        return rram.CrossbarWeight(
            g_pos=out.g_pos.reshape(lead + w.shape[-2:]),
            g_neg=out.g_neg.reshape(lead + w.shape[-2:]),
            scale=out.scale.reshape(lead + (1, w.shape[-1])),
        )
    out = jax.vmap(
        lambda m, kk: rram.drifted_weights(m, cfg, kk, dtype=w.dtype)
    )(flat, keys)
    return out.reshape(lead + w.shape[-2:])


def drift_model(
    base: Pytree,
    cfg: RramConfig,
    key: jax.Array,
    *,
    hours: Optional[float] = None,
    event_index,
    clock_offset: float = 0.0,
    sigma=None,
) -> Pytree:
    """One drift-clock tick over a codes-resident model: re-drift every
    resident ``CrossbarWeight`` WITHOUT reprogramming (the array is never
    rewritten; time simply passes and the conductances relax further).
    ``clock_offset`` is the field time already elapsed before this tick —
    the tick draws the variance INCREMENT over ``[offset, offset+hours]``
    (``rram.drift_sigma_increment``), so slicing the same timeline into
    different ticks accumulates the same total drift.

    Deterministic and replayable: each leaf's event key is
    ``fold_in(fold_in(key, crc32(path)), event_index)``, so a deployment
    that knows its programming key and the ordered list of elapsed-hour
    events can reproduce the exact post-drift codes from scratch
    (``deploy.Deployment.restore`` relies on this).

    Fleet form: ``sigma`` (overriding ``hours``) and ``event_index`` may
    be traced scalars, so ``jax.vmap`` over per-chip ``(codes, key,
    sigma, event_index)`` re-drifts a whole fleet in one dispatch
    (``fleet.Fleet.advance``).
    """
    if (hours is None) == (sigma is None):
        raise ValueError("drift_model needs exactly one of hours= or sigma=")
    n_drifted = 0

    def leaf(path, x):
        nonlocal n_drifted
        if not isinstance(x, rram.CrossbarWeight):
            return x
        n_drifted += 1
        h = jnp.uint32(zlib.crc32(_path_str(path).encode()))
        k = jax.random.fold_in(key, h)
        return rram.apply_drift(
            x, cfg, k, hours=hours, clock_offset=clock_offset,
            event_index=event_index, sigma=sigma,
        )

    out = jax.tree_util.tree_map_with_path(
        leaf, base, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
    if n_drifted == 0:
        raise ValueError(
            "drift_model needs a codes-resident tree (CrossbarWeight leaves); "
            "got a float tree — program with mode='codes' first"
        )
    return out


def rram_bytes(base: Pytree) -> int:
    """Bytes of weights resident in RRAM.

    For a codes-mode tree this is a real MEASUREMENT: the summed byte
    size of the uint8 code arrays actually resident in device memory.
    For a dequant-mode (float) tree it remains the 2-bytes-per-weight
    estimate of what the array WOULD hold (differential uint8 pairs).
    """
    total = 0

    def leaf(path, x):
        nonlocal total
        if isinstance(x, rram.CrossbarWeight):
            total += int(x.g_pos.size) + int(x.g_neg.size)
        elif _is_rram_leaf(path):
            total += 2 * int(x.size)  # G+ and G- codes, 1 byte each
        return x

    jax.tree_util.tree_map_with_path(
        leaf, base, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
    return total


def sram_bytes(adapters: Pytree) -> int:
    """Bytes of calibration state resident in SRAM: the DoRA/LoRA side-car
    arrays at their actual storage width. This is the digital memory the
    paper trades against RRAM rewrites — compare with ``rram_bytes`` on
    the same deployment (serve/train print both at startup).
    """
    total = 0
    for x in jax.tree_util.tree_leaves(adapters):
        if hasattr(x, "nbytes"):
            total += int(x.nbytes)
    return total


def calibrated_fraction(base: Pytree, adapters: Pytree) -> float:
    """Fraction of model parameters that calibration trains (paper's 2.34%
    headline): adapter params / base params, counting a codes-resident
    ``CrossbarWeight`` as its logical weight count."""
    from repro.models.transformer import count_params

    n_base, n_adapters = count_params({"base": base, "adapters": adapters})
    return n_adapters / max(n_base, 1)


def merge_adapters_for_serve(base: Pytree, adapters: Pytree) -> Pytree:
    """Algorithm 2 line 12 over a whole model: replace every DoRA
    ``dora_m`` with ``dora_m_merged = M / ||W_r + A@B||_col`` so serving
    never recomputes weight-sized norms (§Perf H-6).

    Walks base/adapters jointly; adapter dicts are recognized by their
    ``lora_a`` leaf, and the paired base weight is the sibling RRAM leaf.
    """
    from repro.core import dora as dora_lib
    from repro.models.moe import _stacked_column_norm

    def walk(b, a):
        if isinstance(a, dict) and "lora_a" in a:
            if "dora_m" not in a:
                return a  # LoRA: nothing to merge
            w = b["w"] if isinstance(b, dict) and "w" in b else b
            if isinstance(w, rram.CrossbarWeight):
                # codes-resident base: the norm is a one-off digital
                # read-back at deployment; the resulting gamma is exactly
                # what the fused kernel's epilogue consumes.
                w = rram.dequantize(w)
            m = a["dora_m"].astype(jnp.float32)
            # disambiguate by lora_b rank: (r,k) plain/conv; (E,r,k)
            # stacked (experts OR scan groups — same math); (G,E,r,k)
            # scan-stacked expert stacks.
            lb = a["lora_b"]
            if lb.ndim == 2 and w.ndim == 4:  # conv (kh,kw,cin,cout)
                norm = dora_lib.conv_column_norm(w, a["lora_a"], lb)
            elif lb.ndim == 2:
                norm = dora_lib.column_norm(w, a["lora_a"], lb)
            elif lb.ndim == 3:
                norm = _stacked_column_norm(w, a["lora_a"], lb)
            else:
                norm = jax.vmap(_stacked_column_norm)(w, a["lora_a"], lb)
            out = {k: v for k, v in a.items() if k != "dora_m"}
            out["dora_m_merged"] = m / norm
            return out
        if isinstance(a, dict):
            return {
                k: walk(b[k] if isinstance(b, dict) and k in b else b, v)
                for k, v in a.items()
            }
        if isinstance(a, list):
            return [walk(b[i], v) for i, v in enumerate(a)]
        return a

    return walk(base, adapters)


# ---------------------------------------------------------------------------
# Literal per-layer calibration loop (Algorithm 1) — used by the CNN repro
# and exposed for any model that provides per-layer (forward, params).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerCalibResult:
    losses: list
    epochs_run: int


def calibrate_layer(
    layer_fn: Callable[[Pytree, Pytree, jax.Array], jax.Array],
    student_layer_base: Pytree,
    adapter: Pytree,
    teacher_in: jax.Array,
    teacher_out: jax.Array,
    *,
    opt: AdamW = AdamW(lr=1e-3),
    max_epochs: int = 20,
    loss_threshold: float = 0.0,
    batch_size: Optional[int] = None,
) -> Tuple[Pytree, LayerCalibResult]:
    """Algorithm 1 lines 5-10 for a single layer.

    ``layer_fn(base, adapter, x) -> y``. ``teacher_in/out`` are the cached
    clean features for the calibration samples (N leading dim).
    Runs ``max_epochs`` epochs of full-batch Adam (paper uses batch 1 over
    10 samples; full-batch over <=10 samples is the same data regime and
    jit-friendlier — ``batch_size`` restores per-sample updates if set).
    """
    opt_state = adamw_init(adapter)

    def loss_fn(ad, x, y):
        pred = layer_fn(student_layer_base, ad, x)
        d = pred.astype(jnp.float32) - y.astype(jnp.float32)
        return jnp.mean(d * d)

    @jax.jit
    def step(ad, opt_state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(ad, x, y)
        ad, opt_state = adamw_update(grads, opt_state, ad, opt)
        return ad, opt_state, loss

    n = teacher_in.shape[0]
    bs = batch_size or n
    losses = []
    epochs_run = 0
    for epoch in range(max_epochs):
        epoch_loss = 0.0
        for i in range(0, n, bs):
            adapter, opt_state, loss = step(
                adapter, opt_state, teacher_in[i : i + bs], teacher_out[i : i + bs]
            )
            epoch_loss += float(loss) * min(bs, n - i)
        epoch_loss /= n
        losses.append(epoch_loss)
        epochs_run = epoch + 1
        if epoch_loss <= loss_threshold:
            break
    return adapter, LayerCalibResult(losses=losses, epochs_run=epochs_run)


# ---------------------------------------------------------------------------
# Whole-model jitted calibration state (LM stacks) — built by launch/train.py
# ---------------------------------------------------------------------------


class CalibState:
    """Plain pytree container: (teacher_base, student_base, adapters,
    opt_state, step). Registered as a pytree for jit/pjit."""

    def __init__(self, teacher_base, student_base, adapters, opt_state, step):
        self.teacher_base = teacher_base
        self.student_base = student_base
        self.adapters = adapters
        self.opt_state = opt_state
        self.step = step

    def tree_flatten(self):
        return (
            (self.teacher_base, self.student_base, self.adapters,
             self.opt_state, self.step),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    CalibState, CalibState.tree_flatten, CalibState.tree_unflatten
)


def teacher_features(teacher_base, batch, cfg):
    """Algorithm 1 line 3: run the frozen teacher ONCE over the calibration
    batch and cache every block's input/output. With ~10 calibration
    samples the same features serve every epoch — the per-step teacher
    recompute (≈⅓ of step FLOPs and bytes) is amortized away (§Perf H-9).

    Returns a dict of cached teacher activations:

    - ``"dec"`` — (Ld+1, B, S_tot, d): decoder block inputs plus the final
      block output. S_tot includes the vision prefix for VLM configs.
    - ``"enc"`` — (Le+1, B, S_src, d) for enc-dec configs: encoder block
      inputs plus the final (pre-norm) encoder output.
    - ``"enc_out"`` — (B, S_src, d): normed encoder output, the cross-
      attention memory every decoder block (teacher AND student) consumes.
    - ``"head_in"`` / ``"head_out"`` for untied heads: final-norm output
      and the teacher logits it produces (the lm_head lives in RRAM, so
      its side-car is calibrated against cached logits too).
    """
    from repro.models import transformer as T
    import jax.numpy as jnp

    base = teacher_base
    h = T.L.embed(batch["tokens"], base["embed"],
                  scale_by_sqrt_dim=cfg.embed_scale)
    mask = None
    if cfg.vision_tokens and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(h.dtype), h], axis=1)
        mask = T._prefix_mask(h.shape[1], batch["patch_embeds"].shape[1])
    s = h.shape[1]
    positions = jnp.arange(s)[None]
    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period
    out = {}

    enc_out = None
    if cfg.encoder_layers:
        src = batch["enc_embeds"].astype(h.dtype)
        s_src = src.shape[1]
        enc_mask = jnp.ones((s_src, s_src), bool)
        enc_pos = jnp.arange(s_src)[None]

        def enc_run(he, tb):
            return T.block_forward(he, tb, {}, cfg, "attn", "mlp",
                                   positions=enc_pos, mask=enc_mask)

        if cfg.unroll:
            enc_feats = [src]
            he = src
            for tb in base["encoder"]:
                he = enc_run(he, tb)
                enc_feats.append(he)
            enc_feats = jnp.stack(enc_feats)
        else:
            def enc_step(he, tb):
                o = enc_run(he, tb)
                return o, o

            he, ys = jax.lax.scan(enc_step, src, base["encoder"])
            enc_feats = jnp.concatenate([src[None], ys], axis=0)
        enc_out = T._norm(he, base["enc_norm"], cfg)
        out["enc"] = enc_feats  # (Le+1, B, S_src, d)
        out["enc_out"] = enc_out

    feats = [h]

    def run(h, b, kind):
        mixer, ffn = kind
        return T.block_forward(h, b, {}, cfg, mixer, ffn,
                               positions=positions, mask=mask, enc_out=enc_out)

    for i in range(pro):
        h = run(h, base["prologue"][i], kinds[i])
        feats.append(h)
    if n_groups:
        body_kinds = [kinds[pro + j] for j in range(p)]

        def group(h, bs):
            outs = []
            for j in range(p):
                h = run(h, bs[j], body_kinds[j])
                outs.append(h)
            return h, jnp.stack(outs)

        h, ys = jax.lax.scan(group, h, base["body"])  # ys: (G, p, B, S, d)
        feats.extend(list(ys.reshape((-1,) + ys.shape[2:])))
    for j, i in enumerate(range(cfg.n_layers - epi, cfg.n_layers)):
        h = run(h, base["epilogue"][j], kinds[i])
        feats.append(h)
    out["dec"] = jnp.stack(feats)  # (Ld+1, B, S_tot, d)

    if not cfg.tie_lm_head:
        hn = T._norm(h, base["final_norm"], cfg)
        out["head_in"] = hn
        out["head_out"] = T.L.linear(hn, base["lm_head"], {}, cfg.adapter)
    return out


def make_cached_calib_loss(cfg):
    """The cached-teacher calibration loss as a standalone function
    ``loss_fn(adapters, student_base, feats, batch)``: each student
    block sees feats["dec"][l] (/ feats["enc"][l]) and matches the
    cached teacher output at l+1. Mirrors ``feature_calibration_loss``
    term-for-term — encoder pairs, decoder pairs, and the untied
    lm_head logits term, averaged over ``n_terms`` — so cached and
    fused calibration follow the same trajectory. Shared by the
    single-chip/vmapped step below and the mesh-parallel fleet path
    (which needs raw per-chip gradients for the compressed cross-device
    all-reduce)."""
    from repro.models import transformer as T
    import jax.numpy as jnp

    kinds = cfg.layer_kinds()
    pro, n_groups, epi = cfg.body_layout()
    p = cfg.scan_period

    def loss_fn(adapters, sbase, feats, batch):
        dec = feats["dec"]
        s = dec.shape[2]
        positions = jnp.arange(s)[None]
        mask = None
        if cfg.vision_tokens and "patch_embeds" in batch:
            mask = T._prefix_mask(s, batch["patch_embeds"].shape[1])
        loss = jnp.zeros((), jnp.float32)
        n_terms = 0
        enc_out = feats.get("enc_out")

        if cfg.encoder_layers:
            enc = feats["enc"]
            s_src = enc.shape[2]
            enc_mask = jnp.ones((s_src, s_src), bool)
            enc_pos = jnp.arange(s_src)[None]

            if cfg.unroll:
                for l, (sb, a_) in enumerate(
                    zip(sbase["encoder"], adapters.get("encoder"))
                ):
                    s_out = T.block_forward(
                        enc[l], sb, a_, cfg, "attn", "mlp",
                        positions=enc_pos, mask=enc_mask,
                    )
                    loss = loss + T._mse(enc[l + 1], s_out)
            else:
                def enc_pair(carry, xs):
                    acc, idx = carry
                    sb, a_ = xs
                    fin = jax.lax.dynamic_index_in_dim(
                        enc, idx, keepdims=False
                    )
                    fout = jax.lax.dynamic_index_in_dim(
                        enc, idx + 1, keepdims=False
                    )
                    s_out = T.block_forward(
                        fin, sb, a_, cfg, "attn", "mlp",
                        positions=enc_pos, mask=enc_mask,
                    )
                    return (acc + T._mse(fout, s_out), idx + 1), None

                (loss, _), _ = jax.lax.scan(
                    enc_pair, (loss, 0),
                    (sbase["encoder"], adapters.get("encoder")),
                )
            n_terms += cfg.encoder_layers

        def pair(l, b, a_, kind):
            mixer, ffn = kind
            s_out = T.block_forward(
                dec[l], b, a_, cfg, mixer, ffn, positions=positions,
                mask=mask, enc_out=enc_out,
            )
            return T._mse(dec[l + 1], s_out)

        for i in range(pro):
            loss += pair(i, sbase["prologue"][i], adapters["prologue"][i],
                         kinds[i])
            n_terms += 1
        if n_groups:
            body_kinds = [kinds[pro + j] for j in range(p)]
            body_feats = dec[pro:pro + n_groups * p + 1]

            def group(carry, xs):
                acc, idx = carry
                bs, as_ = xs
                for j in range(p):
                    mixer, ffn = body_kinds[j]
                    fin = jax.lax.dynamic_index_in_dim(
                        body_feats, idx * p + j, keepdims=False
                    )
                    fout = jax.lax.dynamic_index_in_dim(
                        body_feats, idx * p + j + 1, keepdims=False
                    )
                    s_out = T.block_forward(
                        fin, bs[j], as_[j], cfg, mixer, ffn,
                        positions=positions, mask=mask, enc_out=enc_out,
                    )
                    acc = acc + T._mse(fout, s_out)
                return (acc, idx + 1), None

            (loss, _), _ = jax.lax.scan(
                group, (loss, 0),
                (sbase["body"], adapters.get("body")),
            )
            n_terms += n_groups * p
        for j, i in enumerate(range(cfg.n_layers - epi, cfg.n_layers)):
            loss += pair(
                pro + n_groups * p + j, sbase["epilogue"][j],
                adapters["epilogue"][j], kinds[i],
            )
            n_terms += 1

        if not cfg.tie_lm_head:
            s_logits = T.L.linear(
                feats["head_in"], sbase["lm_head"],
                adapters.get("lm_head"), cfg.adapter,
            )
            loss = loss + T._mse(feats["head_out"], s_logits)
            n_terms += 1
        return loss / n_terms

    return loss_fn


def make_cached_calib_step(cfg, opt: AdamW = AdamW(lr=1e-3)):
    """Calibration step against cached teacher features: each student
    block sees feats[l] and matches feats[l+1]. Teacher forward cost: 0."""
    loss_fn = make_cached_calib_loss(cfg)

    def step(state: CalibState, feats, batch):
        loss, grads = jax.value_and_grad(loss_fn)(
            state.adapters, state.student_base, feats, batch
        )
        adapters, opt_state = adamw_update(
            grads, state.opt_state, state.adapters, opt
        )
        new_state = CalibState(
            state.teacher_base, state.student_base, adapters, opt_state,
            state.step + 1,
        )
        return new_state, {"loss": loss}

    return step


def make_calib_step(
    cfg,
    opt: AdamW = AdamW(lr=1e-3),
):
    """Build the jittable whole-model calibration step for an LM config."""
    from repro.models import transformer as T

    def calib_step(state: CalibState, batch: Dict) -> Tuple[CalibState, Dict]:
        def loss_fn(adapters):
            return T.feature_calibration_loss(
                state.teacher_base, state.student_base, adapters, batch, cfg
            )

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.adapters
        )
        adapters, opt_state = adamw_update(
            grads, state.opt_state, state.adapters, opt
        )
        new_state = CalibState(
            state.teacher_base,
            state.student_base,
            adapters,
            opt_state,
            state.step + 1,
        )
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return calib_step
