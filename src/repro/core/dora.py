"""DoRA / LoRA adapters for RIMC calibration (paper §III-C, Algorithm 2).

A ``RimcLinear`` is the paper's unit of compensation: a frozen, drifted
base weight (the RRAM crossbar) plus small trainable digital parameters
("SRAM"):

  LoRA:  Y = X @ W_r + (X @ A) @ B                            (eq. 5)
  DoRA:  Y = M ∘ normalize(X @ W_r + (X @ A) @ B)             (training)
         Y = M' ∘ (X @ W_r + (X @ A) @ B)                     (inference,
                      M' = M / ||column||, merged by Algorithm 2 line 12)

where A ∈ R^{d×r} (random init), B ∈ R^{r×k} (zeros — adapter starts as
identity), M ∈ R^{1×k} initialized to the column L2 norm of the *drifted*
base weight so the initial DoRA output equals the plain drifted output.

Following the DoRA paper/Algorithm 2 we treat ``normalize`` as dividing by
the column norm of the *adapted weight* ``W_r + A@B`` (weight-space view);
this is algebraically identical to scaling the output features per column
and keeps inference a single fused epilogue.

The ratio of trainable parameters is eq. 7:
  gamma = (d*r + r*k + k) / (d*k).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdapterConfig:
    rank: int = 4
    # 'dora' | 'lora' | 'none'. 'none' -> base weight only (pure RRAM).
    kind: str = "dora"
    # dtype of the adapter parameters ("SRAM" side). fp32 during training
    # per the paper; int8 PTQ at inference is exercised in tests.
    dtype: object = jnp.float32


def param_ratio(d: int, k: int, r: int) -> float:
    """Eq. 7: proportion of new parameters introduced by DoRA."""
    return (d * r + r * k + k) / (d * k)


def init_adapter(
    key: jax.Array,
    d: int,
    k: int,
    cfg: AdapterConfig,
    w_base: Optional[jax.Array] = None,
) -> dict:
    """Initialize (A, B, M) per Algorithm 2 line 2.

    A: kaiming-uniform random, B: zeros, M: column L2 norm of the base
    weight (so initialization is output-preserving). When ``w_base`` is not
    supplied (abstract init for the dry-run) M starts at ones and is
    re-normalized on first use.
    """
    if cfg.kind == "none":
        return {}
    r = cfg.rank
    bound = 1.0 / math.sqrt(d)
    a = jax.random.uniform(key, (d, r), cfg.dtype, -bound, bound)
    b = jnp.zeros((r, k), cfg.dtype)
    out = {"lora_a": a, "lora_b": b}
    if cfg.kind == "dora":
        if w_base is not None:
            m = jnp.linalg.norm(w_base.astype(jnp.float32), axis=0)
        else:
            m = jnp.ones((k,), jnp.float32)
        out["dora_m"] = m.astype(cfg.dtype)
    return out


def column_norm(
    w_base: jax.Array, a: jax.Array, b: jax.Array, eps: float = 1e-6
) -> jax.Array:
    """||W_r + A@B||_2 per column, computed without materializing A@B in
    low precision: norm² = colnorm²(W) + 2·col(Wᵀ(A@B)) + colnorm²(A@B).

    For small r this is cheaper than forming W + A@B when W is quantized/
    bf16 and we want an f32 norm: each term is a (d,r)/(r,k) contraction.
    """
    wf = w_base.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    w_sq = jnp.sum(wf * wf, axis=0)  # (k,)
    # cross term: sum_d W[d,k] * (A@B)[d,k] = sum_r (WᵀA)[k,r]·B[r,k]
    wta = wf.T @ af  # (k, r)
    cross = jnp.einsum("kr,rk->k", wta, bf)
    ab_sq = jnp.sum((af @ bf) ** 2, axis=0) if a.shape[1] <= 64 else None
    if ab_sq is None:  # pragma: no cover - large-r fallback
        ab = af @ bf
        ab_sq = jnp.sum(ab * ab, axis=0)
    return jnp.sqrt(jnp.maximum(w_sq + 2.0 * cross + ab_sq, eps))


def adapted_forward(
    x: jax.Array,
    w_base: jax.Array,
    adapter: dict,
    cfg: AdapterConfig,
    *,
    merged_norm: Optional[jax.Array] = None,
) -> jax.Array:
    """Forward through base + adapter (Algorithm 2 lines 5-7).

    x: (..., d); w_base: (d, k) frozen drifted weight.
    merged_norm: optional precomputed ||W_r + A@B|| column norms. When given
    (inference path after Algorithm 2 line 12's merge) the normalization is
    a static per-column scale; when None (training) the norm is recomputed
    from the live adapter so its gradient flows into A and B as in DoRA.
    """
    compute_dtype = x.dtype
    y = x @ w_base.astype(compute_dtype)
    if cfg.kind == "none" or not adapter:
        return y
    a = adapter["lora_a"].astype(compute_dtype)
    b = adapter["lora_b"].astype(compute_dtype)
    y = y + (x @ a) @ b
    if cfg.kind == "lora":
        return y
    if "dora_m_merged" in adapter:
        # Algorithm 2 line 12: M already divided by ||W_r + A@B|| at
        # deployment — per-step norm recompute (a weight-sized f32 op that
        # also forced SPMD weight gathers) is gone (§Perf H-6).
        return y * adapter["dora_m_merged"].astype(compute_dtype)
    m = adapter["dora_m"].astype(jnp.float32)
    if merged_norm is None:
        norm = column_norm(w_base, adapter["lora_a"], adapter["lora_b"])
    else:
        norm = merged_norm
    scale = (m / norm).astype(compute_dtype)
    return y * scale


def merge_magnitude(
    w_base: jax.Array, adapter: dict, cfg: AdapterConfig
) -> Optional[jax.Array]:
    """Algorithm 2 line 12: precompute ||W_r + A@B|| for inference.

    Returns the merged column norms (to pass as ``merged_norm``), or None
    for non-DoRA adapters.
    """
    if cfg.kind != "dora" or not adapter:
        return None
    return column_norm(w_base, adapter["lora_a"], adapter["lora_b"])


def quantize_adapter_int8(adapter: dict) -> dict:
    """Paper §III-C: adapters are stored int8 at inference. Symmetric
    per-tensor PTQ; returns {name: (codes_int8, scale_f32)}."""
    out = {}
    for name, v in adapter.items():
        absmax = jnp.maximum(jnp.max(jnp.abs(v)), 1e-8)
        scale = absmax / 127.0
        codes = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
        out[name] = (codes, scale)
    return out


def dequantize_adapter_int8(qadapter: dict, dtype=jnp.float32) -> dict:
    return {
        name: (codes.astype(jnp.float32) * scale).astype(dtype)
        for name, (codes, scale) in qadapter.items()
    }


def adapter_param_count(d: int, k: int, cfg: AdapterConfig) -> int:
    if cfg.kind == "none":
        return 0
    n = d * cfg.rank + cfg.rank * k
    if cfg.kind == "dora":
        n += k
    return n


# ---------------------------------------------------------------------------
# Convolutional DoRA (for the paper-faithful ResNet reproduction)
# ---------------------------------------------------------------------------
#
# A conv weight (kh, kw, cin, cout) is logically the matmul weight
# (d = kh*kw*cin, k = cout) over im2col patches. The low-rank path is
# realized as a (kh, kw, cin, r) conv followed by a 1x1 (r, cout) conv, and
# M scales output channels — the direct conv analogue of Algorithm 2.


def init_conv_adapter(
    key: jax.Array,
    kh: int,
    kw: int,
    cin: int,
    cout: int,
    cfg: AdapterConfig,
    w_base: Optional[jax.Array] = None,
) -> dict:
    if cfg.kind == "none":
        return {}
    d = kh * kw * cin
    bound = 1.0 / math.sqrt(d)
    a = jax.random.uniform(key, (kh, kw, cin, cfg.rank), cfg.dtype, -bound, bound)
    b = jnp.zeros((cfg.rank, cout), cfg.dtype)
    out = {"lora_a": a, "lora_b": b}
    if cfg.kind == "dora":
        if w_base is not None:
            m = jnp.linalg.norm(
                w_base.astype(jnp.float32).reshape(-1, cout), axis=0
            )
        else:
            m = jnp.ones((cout,), jnp.float32)
        out["dora_m"] = m.astype(cfg.dtype)
    return out


def conv_column_norm(
    w_base: jax.Array, a: jax.Array, b: jax.Array, eps: float = 1e-6
) -> jax.Array:
    cout = w_base.shape[-1]
    wf = w_base.astype(jnp.float32).reshape(-1, cout)
    af = a.astype(jnp.float32).reshape(-1, a.shape[-1])
    bf = b.astype(jnp.float32)
    ab = af @ bf
    return jnp.sqrt(jnp.maximum(jnp.sum((wf + ab) ** 2, axis=0), eps))


def adapted_conv_forward(
    x: jax.Array,
    w_base: jax.Array,
    adapter: dict,
    cfg: AdapterConfig,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: str = "SAME",
) -> jax.Array:
    """NHWC conv through drifted base + DoRA/LoRA side-car."""
    dn = jax.lax.conv_dimension_numbers(
        x.shape, w_base.shape, ("NHWC", "HWIO", "NHWC")
    )
    y = jax.lax.conv_general_dilated(
        x, w_base.astype(x.dtype), stride, padding, dimension_numbers=dn
    )
    if cfg.kind == "none" or not adapter:
        return y
    a = adapter["lora_a"].astype(x.dtype)
    b = adapter["lora_b"].astype(x.dtype)
    dn_a = jax.lax.conv_dimension_numbers(
        x.shape, a.shape, ("NHWC", "HWIO", "NHWC")
    )
    xa = jax.lax.conv_general_dilated(
        x, a, stride, padding, dimension_numbers=dn_a
    )
    y = y + xa @ b
    if cfg.kind == "lora":
        return y
    m = adapter["dora_m"].astype(jnp.float32)
    norm = conv_column_norm(w_base, adapter["lora_a"], adapter["lora_b"])
    return y * (m / norm).astype(x.dtype)
