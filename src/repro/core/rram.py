"""RRAM crossbar compact model: conductance mapping, programming, drift.

Implements Section II of the paper:

  * weights are linearly scaled onto the device conductance range ``G_max``
    and programmed as a *differential pair* ``(G+, G-)`` of devices
    (eq. 2):   ``W_r = (G+ - G-) * W_max / G_max``
  * conductance relaxation drift is Gaussian (eq. 1):
    ``G_r = G_t + G_drift``,  ``G_drift ~ N(mu, sigma^2)`` with
    ``relative_drift = sigma / G_max`` (paper Fig. 2 uses sigma/G*).

On TPU the "crossbar" is a frozen int8 tensor pair in HBM; programming and
drift are *simulated* once per deployment (a "programming event") with a
deterministic PRNG key, then the codes are static — calibration never
rewrites them (the paper's whole point).

All functions are pure jnp and jit-safe.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RramConfig:
    """Device/array parameters for the simulated RRAM crossbar."""

    # Conductance quantization levels per device. 8-bit programming DACs
    # are typical for analog RRAM macros; codes are [0, levels-1].
    levels: int = 256
    # Relative drift sigma / G_max (paper: <= 20% of G_t; Fig. 2 sweeps
    # 0.05..0.20). 0.0 disables drift (ideal array).
    relative_drift: float = 0.0
    # Mean drift (paper assumes mu ~ 0 after stabilization).
    drift_mu: float = 0.0
    # Programming (write-and-verify) residual error, relative to G_max.
    # Separate knob from relaxation drift; default 0 (perfect verify).
    programming_sigma: float = 0.0
    # ADC bit-width for the column readout. MVM partial sums saturate at
    # +-(2**(adc_bits-1)-1) ADC steps when simulate_adc is on.
    adc_bits: int = 8
    # Rows simultaneously activated per crossbar MVM (array height).
    array_rows: int = 256
    # Whether the MVM simulation applies ADC quantization (slower, used by
    # the Pallas crossbar kernel & fidelity tests; the LM-scale models use
    # the dequantized fast path which is numerically equivalent w/o ADC).
    simulate_adc: bool = False

    @property
    def code_max(self) -> int:
        return self.levels - 1


# Default config used by the LM stacks: pure drift model, no ADC.
DEFAULT_RRAM = RramConfig()


# ---------------------------------------------------------------------------
# Programming: float weights -> differential int8 conductance codes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CrossbarWeight:
    """A weight tensor as programmed onto RRAM.

    ``g_pos``/``g_neg`` are uint8 conductance codes (0..levels-1) holding the
    positive/negative halves of the differential pair. ``scale`` converts the
    code difference back to weight units: ``W = (g_pos - g_neg) * scale``.
    ``scale`` is per-output-channel (last axis), matching per-column
    programming in real macros.
    """

    g_pos: jax.Array  # uint8, same shape as the logical weight
    g_neg: jax.Array  # uint8
    scale: jax.Array  # f32, shape (..., 1, k) broadcastable over rows

    def tree_flatten(self):
        return (self.g_pos, self.g_neg, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    CrossbarWeight, CrossbarWeight.tree_flatten, CrossbarWeight.tree_unflatten
)


def program(
    w: jax.Array,
    cfg: RramConfig = DEFAULT_RRAM,
    *,
    key: Optional[jax.Array] = None,
) -> CrossbarWeight:
    """Program float weights onto the simulated crossbar.

    Positive weights map to G+ (G- = 0) and negative weights to G-
    (G+ = 0) — the standard differential encoding. Per-column scaling uses
    the column absmax so each column exercises the full conductance range
    (real macros program column-wise with a shared DAC reference).

    If ``key`` is given and ``cfg.programming_sigma > 0``, write-and-verify
    residual noise is added to the codes before rounding.
    """
    w = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
    absmax = jnp.maximum(absmax, 1e-8)
    scale = absmax / cfg.code_max  # weight units per conductance code
    codes = w / scale  # signed, in [-code_max, code_max]
    g_pos = jnp.clip(codes, 0, cfg.code_max)
    g_neg = jnp.clip(-codes, 0, cfg.code_max)
    if key is not None and cfg.programming_sigma > 0.0:
        kp, kn = jax.random.split(key)
        sp = cfg.programming_sigma * cfg.code_max
        g_pos = g_pos + sp * jax.random.normal(kp, g_pos.shape)
        g_neg = g_neg + sp * jax.random.normal(kn, g_neg.shape)
    g_pos = jnp.clip(jnp.round(g_pos), 0, cfg.code_max).astype(jnp.uint8)
    g_neg = jnp.clip(jnp.round(g_neg), 0, cfg.code_max).astype(jnp.uint8)
    return CrossbarWeight(g_pos=g_pos, g_neg=g_neg, scale=scale)


# Reference relaxation time constant for the drift clock: sigma(t) grows
# log-linearly with elapsed time (conductance relaxation is log-time in
# filamentary RRAM), normalized so sigma(tau*(e-1) ~ 41h) equals the
# config's relative_drift.
DRIFT_TAU_HOURS = 24.0


def drift_sigma(cfg: RramConfig, hours: float) -> float:
    """TOTAL relative drift sigma accumulated over ``hours`` of field time
    since programming.

    Log-time relaxation model: ``sigma(t) = relative_drift *
    log1p(t / DRIFT_TAU_HOURS)``. ``hours=0`` means no elapsed time (no
    drift); the config's ``relative_drift`` is reached after
    ``DRIFT_TAU_HOURS * (e - 1)`` hours.
    """
    if hours < 0:
        raise ValueError(f"drift clock cannot run backwards (hours={hours})")
    return float(cfg.relative_drift * np.log1p(hours / DRIFT_TAU_HOURS))


def drift_sigma_increment(cfg: RramConfig, t0: float, hours: float) -> float:
    """Sigma for ONE drift tick covering field time ``[t0, t0 + hours]``.

    Independent Gaussian increments add in variance, so the tick draws
    ``sqrt(sigma(t0+hours)^2 - sigma(t0)^2)`` — the same total elapsed
    time accumulates (to first order; drift compounds on the already-
    drifted conductance) the same total drift no matter how the clock is
    sliced: one ``advance(24)`` matches 24x ``advance(1)`` in variance.
    """
    s1 = drift_sigma(cfg, t0 + hours)
    s0 = drift_sigma(cfg, t0)
    return float(np.sqrt(max(s1 * s1 - s0 * s0, 0.0)))


def apply_drift(
    xw: CrossbarWeight,
    cfg: RramConfig,
    key: jax.Array,
    *,
    hours: Optional[float] = None,
    clock_offset: float = 0.0,
    event_index=None,
    sigma=None,
) -> CrossbarWeight:
    """Apply Gaussian conductance relaxation drift (eq. 1) to programmed codes.

    Drift acts on *conductances* (each device of the pair independently),
    sigma expressed relative to G_max (= code_max in code units). Codes are
    clipped to the physical range; devices at G=0 can only drift upward
    (a formed device cannot have negative conductance).

    The result is quantized back to the code grid only for storage
    compactness; fidelity tests confirm the quantization error is << sigma.

    Drift-clock form (``deploy.Deployment.advance``): ``hours`` selects
    the log-time sigma via ``drift_sigma_increment`` — the variance
    increment over ``[clock_offset, clock_offset + hours]`` of field
    time, so the accumulated drift is invariant to how the timeline is
    sliced into ticks — and ``event_index`` folds the event counter into
    ``key`` so each tick draws independent noise while the full history
    stays exactly replayable from the deployment key alone.

    Fleet (vmapped) form: ``sigma`` overrides the hours-based computation
    and — like ``event_index`` — may be a traced scalar, so a whole fleet
    of chips drifts in ONE batched call (``jax.vmap`` over per-chip
    ``(key, sigma, event_index)``). A traced sigma skips the Python-level
    ``sigma <= 0`` early-out; callers batching over chips pre-filter
    zero-sigma chips (``fleet.Fleet.advance`` does).
    """
    if sigma is None:
        sigma = (
            cfg.relative_drift if hours is None
            else drift_sigma_increment(cfg, clock_offset, hours)
        )
    if isinstance(sigma, (int, float)) and sigma <= 0.0:
        return xw
    if event_index is not None:
        key = jax.random.fold_in(key, jnp.uint32(event_index))
    kp, kn = jax.random.split(key)
    # Drift scales with each cell's programmed conductance: the paper
    # bounds |G_drift| by a FRACTION OF G_t ("generally less than 20% of
    # G_t", §II-A), i.e. G_r = G_t * (1 + N(mu, sigma_rel^2)). Unformed
    # cells (G=0) hold no filament state and stay at 0.
    gp = xw.g_pos.astype(jnp.float32)
    gn = xw.g_neg.astype(jnp.float32)
    drift_p = gp * (cfg.drift_mu + sigma * jax.random.normal(kp, gp.shape))
    drift_n = gn * (cfg.drift_mu + sigma * jax.random.normal(kn, gn.shape))
    g_pos = jnp.clip(gp + drift_p, 0, cfg.code_max)
    g_neg = jnp.clip(gn + drift_n, 0, cfg.code_max)
    return CrossbarWeight(
        g_pos=jnp.round(g_pos).astype(jnp.uint8),
        g_neg=jnp.round(g_neg).astype(jnp.uint8),
        scale=xw.scale,
    )


def dequantize(xw: CrossbarWeight, dtype=jnp.float32) -> jax.Array:
    """Read the effective weight matrix back out of the crossbar codes."""
    diff = xw.g_pos.astype(jnp.float32) - xw.g_neg.astype(jnp.float32)
    return (diff * xw.scale).astype(dtype)


# ---------------------------------------------------------------------------
# Fast functional drift path used by the LM stacks
# ---------------------------------------------------------------------------
#
# Programming + drifting every multi-billion-parameter tensor through uint8
# round-trips is exact but doubles storage during setup. The LM stacks use
# this fused path: W_r = dequantize(drift(program(W))) computed in one shot,
# storing only the drifted float (bf16) result. Equivalence with the
# explicit path is covered by tests/test_rram.py.


def drifted_weights(
    w: jax.Array,
    cfg: RramConfig,
    key: jax.Array,
    dtype=jnp.bfloat16,
) -> jax.Array:
    """W -> program -> drift -> dequantize, fused; returns drifted weights."""
    return dequantize(programmed_codes(w, cfg, key), dtype=dtype)


def programmed_codes(
    w: jax.Array,
    cfg: RramConfig,
    key: jax.Array,
) -> CrossbarWeight:
    """W -> program -> drift, KEEPING the uint8 codes resident.

    This is the substrate's ``codes`` representation: the same programming
    event as ``drifted_weights`` (identical codes for identical keys — the
    backend-parity contract), but the array stays in code space so the
    execution backends (``repro/substrate``) can read it without ever
    materializing a float weight in HBM.
    """
    return apply_drift(program(w, cfg), cfg, key)


# ---------------------------------------------------------------------------
# Reference crossbar MVM with ADC (oracle for the Pallas kernel)
# ---------------------------------------------------------------------------


def mvm_reference(
    x: jax.Array,
    xw: CrossbarWeight,
    cfg: RramConfig,
) -> jax.Array:
    """Simulated analog MVM: row-blocked accumulation with ADC saturation.

    The array activates ``cfg.array_rows`` rows at a time; each block's
    differential column current is digitized by an ADC with ``adc_bits``
    (saturating), then blocks are accumulated digitally. Without ADC
    simulation this reduces to ``x @ dequantize(xw)``.
    """
    if not cfg.simulate_adc:
        return x @ dequantize(xw)
    d = x.shape[-1]
    rows = cfg.array_rows
    n_blocks = (d + rows - 1) // rows
    pad = n_blocks * rows - d
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    gp = jnp.pad(xw.g_pos.astype(jnp.float32), [(0, pad), (0, 0)])
    gn = jnp.pad(xw.g_neg.astype(jnp.float32), [(0, pad), (0, 0)])
    # Per-block input absmax sets the DAC range; ADC full-scale covers the
    # worst-case column current of a block.
    adc_max = 2.0 ** (cfg.adc_bits - 1) - 1.0
    out = jnp.zeros(x.shape[:-1] + (xw.g_pos.shape[-1],), jnp.float32)
    for b in range(n_blocks):
        xs = xp[..., b * rows : (b + 1) * rows]
        gps = gp[b * rows : (b + 1) * rows]
        gns = gn[b * rows : (b + 1) * rows]
        cur = xs @ (gps - gns)  # differential column current
        # ADC step: full scale = rows * code_max * x_absmax / adc_max
        x_absmax = jnp.maximum(jnp.max(jnp.abs(xs)), 1e-8)
        step = rows * cfg.code_max * x_absmax / (adc_max * 16.0)
        cur = jnp.clip(jnp.round(cur / step), -adc_max, adc_max) * step
        out = out + cur
    return out * xw.scale.reshape((1,) * (out.ndim - 1) + (-1,))


# ---------------------------------------------------------------------------
# Lifespan / speed analytical model (paper Table I)
# ---------------------------------------------------------------------------

RRAM_ENDURANCE = 1e8  # write cycles
SRAM_ENDURANCE = 1e16
RRAM_WRITE_NS = 100.0  # write-and-verify per cell
SRAM_WRITE_NS = 1.0  # ~100x faster than RRAM


def lifespan_calibrations(
    *,
    samples: int,
    epochs: int = 20,
    batch: int = 1,
    on_rram: bool,
) -> float:
    """How many calibrations before the storage wears out (Table I).

    Backprop-on-RRAM updates the array once per optimizer step:
    ``epochs * samples / batch`` writes per calibration against 1e8
    endurance. DoRA updates SRAM instead (1e16 endurance).
    """
    updates = epochs * (samples / batch)
    endurance = RRAM_ENDURANCE if on_rram else SRAM_ENDURANCE
    return endurance / updates


def calibration_speedup(
    *,
    base_samples: int = 125,
    dora_samples: int = 10,
    rram_write_ns: float = RRAM_WRITE_NS,
    sram_write_ns: float = SRAM_WRITE_NS,
) -> float:
    """Weight-update-bound speedup of DoRA/SRAM calibration vs backprop/RRAM.

    Paper §IV-E: update count scales with dataset fraction (10/125 = 8%)
    and each update is ~100x faster on SRAM -> 1250x.
    """
    update_ratio = base_samples / dora_samples
    write_ratio = rram_write_ns / sram_write_ns
    return update_ratio * write_ratio
