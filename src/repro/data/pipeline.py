"""Deterministic, stateless calibration-data pipeline.

Calibration needs only ~10 samples (the paper's headline), but at
framework scale the pipeline must still be: deterministic (step -> batch,
no loader state to checkpoint), shardable (each data-parallel host
materializes only its slice), and restartable (recovery resumes from the
step counter alone — see runtime/fault.py).

``step -> batch`` is a pure function of (seed, step), implemented with
counter-based threefry keys, so elastic re-scaling to a different dp size
replays the exact same global batch split differently — no data loss or
duplication on failover.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # calibration set size: batches cycle over this many distinct samples
    # (paper: 10). 0 -> unlimited fresh stream.
    n_calibration_samples: int = 10
    # enc-dec / vlm stubs
    enc_src_len: int = 0
    d_model: int = 0
    vision_tokens: int = 0


def _sample_key(cfg: DataConfig, sample_idx: jax.Array) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed), sample_idx)


def global_batch_at_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Materialize the full global batch (host-side, numpy) for ``step``."""
    return _slice_batch(cfg, step, 0, cfg.global_batch)


def shard_batch_at_step(
    cfg: DataConfig, step: int, shard: int, n_shards: int
) -> Dict[str, np.ndarray]:
    """Materialize only this host's slice of the global batch."""
    per = cfg.global_batch // n_shards
    return _slice_batch(cfg, step, shard * per, per)


def _slice_batch(cfg: DataConfig, step: int, start: int, count: int):
    rows = np.arange(start, start + count)
    sample_ids = (step * cfg.global_batch + rows) % max(
        cfg.n_calibration_samples or (1 << 31), 1
    )
    keys = jax.vmap(lambda i: _sample_key(cfg, i))(jnp.asarray(sample_ids))
    tokens = jax.vmap(
        lambda k: jax.random.randint(k, (cfg.seq_len,), 0, cfg.vocab)
    )(keys)
    out = {"tokens": np.asarray(tokens, np.int32)}
    if cfg.enc_src_len and cfg.d_model:
        embeds = jax.vmap(
            lambda k: jax.random.normal(
                jax.random.fold_in(k, 1), (cfg.enc_src_len, cfg.d_model)
            )
        )(keys)
        out["enc_embeds"] = np.asarray(embeds, np.float32).astype(np.float32)
    if cfg.vision_tokens and cfg.d_model:
        patches = jax.vmap(
            lambda k: jax.random.normal(
                jax.random.fold_in(k, 2), (cfg.vision_tokens, cfg.d_model)
            )
        )(keys)
        out["patch_embeds"] = np.asarray(patches, np.float32)
    return out


def batches(cfg: DataConfig, start_step: int = 0):
    """Infinite deterministic iterator (resume by passing the restored
    step counter)."""
    step = start_step
    while True:
        yield step, global_batch_at_step(cfg, step)
        step += 1
