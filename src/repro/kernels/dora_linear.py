"""Fused RIMC-DoRA linear kernels (Pallas TPU).

Computes, in one pass over the crossbar codes (paper eq. 2 + eq. 6):

    Y = (X @ W_r + (X @ A) @ B) * gamma
    W_r = (G+ - G-) * scale          (differential int8 conductance pair)
    gamma = M / ||W_r + A@B||_col    (DoRA magnitude / merged column norm)

Two launchers over the same kernel bodies:

* ``dora_linear`` — prefill-shaped: grid (M/bm, N/bn, K/bk), K innermost
  so the accumulators live in VMEM scratch across the K loop (MXU-aligned
  tiles at full size).
* ``dora_linear_gemv`` — decode-shaped: M is a single sublane-aligned
  block (a handful of active slots), the grid is (N/bn, K/bk) with the
  K-parallel accumulator reduction only. No M axis means no 128-row pad
  of a 2-row decode batch (ISSUE 6 tentpole 1).

Both take ``accum``:

* ``"f32"``  — codes are dequantized in-register per tile
  ((G+ - G-) as f32) and accumulated on the MXU in f32.
* ``"int8"`` — integer MMA: x is quantized per-row to s8, codes are
  offset-recoded u8 -> s8 (``g - 128``; the offsets cancel exactly in the
  differential combine, so the integer dot of the recoded pair equals
  ``x_q @ (G+ - G-)``), both dots run with
  ``preferred_element_type=jnp.int32``, and the per-row x scale plus the
  per-column code scale fold into the f32 epilogue together with the
  low-rank path.

The low-rank path rides the same K loop: per K-tile we accumulate XA
(bm, r) — r is tiny (4..64) — and the last K step applies (XA)@B and the
DoRA scale. ``gamma`` is precomputed at merge time (Algorithm 2 line 12)
by ``ops.dora_gamma``; tile selection lives in ``kernels/autotune.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, gp_ref, gn_ref, scale_ref, a_ref, b_ref, gamma_ref,
            o_ref, acc_ref, xa_ref, *, n_k: int, k_axis: int):
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    # in-register differential dequant: int8 codes -> f32 weights
    w = (gp_ref[...].astype(jnp.float32) - gn_ref[...].astype(jnp.float32))
    acc_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )
    xa_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = scale_ref[...]  # (1, bn) per-column code scale
        lowrank = jax.lax.dot(
            xa_ref[...], b_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        y = acc_ref[...] * scale + lowrank
        o_ref[...] = (y * gamma_ref[...]).astype(o_ref.dtype)


def _kernel_int8(x_ref, xs_ref, gp_ref, gn_ref, scale_ref, a_ref, b_ref,
                 gamma_ref, o_ref, acc_ref, xa_ref, *, n_k: int, k_axis: int):
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    xq = x_ref[...]  # s8, rows scaled by xs
    # integer MMA on the recoded differential pair: the -128 offsets of
    # g_pos/g_neg cancel, so this int32 sum is exactly x_q @ (G+ - G-).
    acc_ref[...] += jax.lax.dot(
        xq, gp_ref[...], preferred_element_type=jnp.int32
    ) - jax.lax.dot(xq, gn_ref[...], preferred_element_type=jnp.int32)
    xa_ref[...] += jax.lax.dot(
        xq.astype(jnp.float32), a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        xs = xs_ref[...]  # (bm, 1) per-row x quantization scale
        lowrank = jax.lax.dot(
            xa_ref[...] * xs, b_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        y = acc_ref[...].astype(jnp.float32) * xs * scale_ref[...] + lowrank
        o_ref[...] = (y * gamma_ref[...]).astype(o_ref.dtype)


def _quantize_rows(x: jax.Array):
    """Per-row symmetric s8 quantization: x ~= x_q * xs (xs f32 (M, 1))."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    xs = jnp.maximum(absmax, 1e-30) / 127.0
    xq = jnp.clip(jnp.round(xf / xs), -127, 127).astype(jnp.int8)
    return xq, xs


def recode_s8(g: jax.Array) -> jax.Array:
    """Offset recode u8 codes to s8 (``g - 128``). Exact for the
    differential pair: the offsets cancel in ``(G+ - 128) - (G- - 128)``."""
    if g.dtype == jnp.int8:
        return g
    return (g.astype(jnp.int16) - 128).astype(jnp.int8)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "out_dtype", "accum"),
)
def dora_linear(
    x: jax.Array,       # (M, K)
    g_pos: jax.Array,   # (K, N) uint8 (or s8 when pre-recoded)
    g_neg: jax.Array,   # (K, N) uint8 (or s8 when pre-recoded)
    scale: jax.Array,   # (1, N) f32 — code->weight scale per column
    a: jax.Array,       # (K, r)
    b: jax.Array,       # (r, N)
    gamma: jax.Array,   # (1, N) f32 — merged DoRA magnitude M/||.||
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
    accum: str = "f32",
):
    m, k = x.shape
    _, n = g_pos.shape
    r = a.shape[1]
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    operand_specs = [
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # g_pos
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # g_neg
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),     # scale
        pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),    # a
        pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),     # b
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),     # gamma
    ]
    if accum == "int8":
        xq, xs = _quantize_rows(x)
        kernel = functools.partial(_kernel_int8, n_k=n_k, k_axis=2)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),  # x_q
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),    # x row scale
        ] + operand_specs
        acc_dtype = jnp.int32
        args = (xq, xs, recode_s8(g_pos), recode_s8(g_neg))
    else:
        assert accum == "f32", accum
        kernel = functools.partial(_kernel, n_k=n_k, k_axis=2)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),  # x
        ] + operand_specs
        acc_dtype = jnp.float32
        args = (x, g_pos, g_neg)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), acc_dtype),    # main accumulator
            pltpu.VMEM((bm, r), jnp.float32),   # low-rank XA accumulator
        ],
        interpret=interpret,
    )(*args, scale, a, b, gamma)


@functools.partial(
    jax.jit,
    static_argnames=("bn", "bk", "interpret", "out_dtype", "accum"),
)
def dora_linear_gemv(
    x: jax.Array,       # (M, K), M small (one decode batch) — no M grid
    g_pos: jax.Array,   # (K, N)
    g_neg: jax.Array,   # (K, N)
    scale: jax.Array,   # (1, N)
    a: jax.Array,       # (K, r)
    b: jax.Array,       # (r, N)
    gamma: jax.Array,   # (1, N)
    *,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
    accum: str = "f32",
):
    """Decode-shaped variant: the whole (small) M is one block and the
    grid is (N/bn, K/bk) with K innermost — the accumulator reduction
    without the M axis, so a 2-row decode tick never pads to 128 rows."""
    m, k = x.shape
    _, n = g_pos.shape
    r = a.shape[1]
    assert n % bn == 0 and k % bk == 0, (m, n, k, bn, bk)
    n_k = k // bk
    grid = (n // bn, n_k)
    operand_specs = [
        pl.BlockSpec((bk, bn), lambda j, kk: (kk, j)),   # g_pos
        pl.BlockSpec((bk, bn), lambda j, kk: (kk, j)),   # g_neg
        pl.BlockSpec((1, bn), lambda j, kk: (0, j)),     # scale
        pl.BlockSpec((bk, r), lambda j, kk: (kk, 0)),    # a
        pl.BlockSpec((r, bn), lambda j, kk: (0, j)),     # b
        pl.BlockSpec((1, bn), lambda j, kk: (0, j)),     # gamma
    ]
    if accum == "int8":
        xq, xs = _quantize_rows(x)
        kernel = functools.partial(_kernel_int8, n_k=n_k, k_axis=1)
        in_specs = [
            pl.BlockSpec((m, bk), lambda j, kk: (0, kk)),  # x_q
            pl.BlockSpec((m, 1), lambda j, kk: (0, 0)),    # x row scale
        ] + operand_specs
        acc_dtype = jnp.int32
        args = (xq, xs, recode_s8(g_pos), recode_s8(g_neg))
    else:
        assert accum == "f32", accum
        kernel = functools.partial(_kernel, n_k=n_k, k_axis=1)
        in_specs = [
            pl.BlockSpec((m, bk), lambda j, kk: (0, kk)),  # x
        ] + operand_specs
        acc_dtype = jnp.float32
        args = (x, g_pos, g_neg)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, bn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((m, bn), acc_dtype),     # main accumulator
            pltpu.VMEM((m, r), jnp.float32),    # low-rank XA accumulator
        ],
        interpret=interpret,
    )(*args, scale, a, b, gamma)
