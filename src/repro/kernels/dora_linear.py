"""Fused RIMC-DoRA linear kernel (Pallas TPU).

Computes, in one pass over the crossbar codes (paper eq. 2 + eq. 6):

    Y = (X @ W_r + (X @ A) @ B) * gamma
    W_r = (G+ - G-) * scale          (differential int8 conductance pair)
    gamma = M / ||W_r + A@B||_col    (DoRA magnitude / merged column norm)

TPU mapping (DESIGN.md §2):
  * grid (M/bm, N/bn, K/bk); K innermost so the f32 accumulators live in
    VMEM scratch across the K loop (MXU-aligned tiles, bm/bn/bk multiples
    of 128 at full size).
  * the int8->bf16 dequant of (G+ - G-) happens in-register per tile —
    HBM traffic is 2 bytes/weight of codes instead of 2 bytes of bf16
    PLUS it never materializes W_r in HBM (the RRAM array is read-only).
  * the low-rank path rides the same K loop: per K-tile we accumulate
    XA (bm, r) — r is tiny (4..64), so the extra VMEM is negligible; at
    the last K step the epilogue applies (XA)@B and the DoRA scale.

``gamma`` is precomputed at load time (Algorithm 2 line 12 merge) by
``ops.dora_gamma`` — the kernel itself is inference/serving-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, gp_ref, gn_ref, scale_ref, a_ref, b_ref, gamma_ref,
            o_ref, acc_ref, xa_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    # in-register differential dequant: int8 codes -> f32 weights
    w = (gp_ref[...].astype(jnp.float32) - gn_ref[...].astype(jnp.float32))
    acc_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    )
    xa_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), a_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        scale = scale_ref[...]  # (1, bn) per-column code scale
        lowrank = jax.lax.dot(
            xa_ref[...], b_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        y = acc_ref[...] * scale + lowrank
        o_ref[...] = (y * gamma_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"),
)
def dora_linear(
    x: jax.Array,       # (M, K)
    g_pos: jax.Array,   # (K, N) uint8
    g_neg: jax.Array,   # (K, N) uint8
    scale: jax.Array,   # (1, N) f32 — code->weight scale per column
    a: jax.Array,       # (K, r)
    b: jax.Array,       # (r, N)
    gamma: jax.Array,   # (1, N) f32 — merged DoRA magnitude M/||.||
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    m, k = x.shape
    _, n = g_pos.shape
    r = a.shape[1]
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # x
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # g_pos
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # g_neg
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),     # scale
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),    # a
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),     # b
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),     # gamma
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),  # main accumulator
            pltpu.VMEM((bm, r), jnp.float32),   # low-rank XA accumulator
        ],
        interpret=interpret,
    )(x, g_pos, g_neg, scale, a, b, gamma)
