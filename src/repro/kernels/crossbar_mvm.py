"""Analog crossbar MVM kernel with ADC quantization (Pallas TPU).

Simulates the analog signal chain of an RIMC macro (paper §II-A /
Fig. 1b) at tile granularity:

  * each K-tile of ``array_rows`` rows is one physical crossbar activation:
    the differential column current ``x_blk @ (G+ - G-)`` is formed in f32
    (the MXU stands in for the analog dot product),
  * the current is digitized by a saturating ``adc_bits`` ADC (round +
    clip to +-(2^(b-1)-1) steps) — quantization noise and saturation are
    faithfully modeled per tile,
  * digitized partial sums accumulate in VMEM scratch across K-tiles
    (digital shift-and-add periphery),
  * the final column scale converts code units back to weight units.

The K block size IS the crossbar height: ``bk == array_rows`` (256 for
the default RramConfig). The ADC step matches core/rram.py::mvm_reference
(full-scale = rows * code_max * x_absmax / (adc_max * 64)) — ref.py is
the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, gp_ref, gn_ref, scale_ref, o_ref, acc_ref,
            *, n_k: int, code_max: int, adc_bits: int, rows: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = gp_ref[...].astype(jnp.float32) - gn_ref[...].astype(jnp.float32)
    cur = jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    # per-tile ADC: full scale tracks the tile's input magnitude (the DAC
    # reference), matching core/rram.py::mvm_reference exactly.
    adc_max = 2.0 ** (adc_bits - 1) - 1.0
    x_absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    step = rows * code_max * x_absmax / (adc_max * 16.0)
    cur = jnp.clip(jnp.round(cur / step), -adc_max, adc_max) * step
    acc_ref[...] += cur

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...] * scale_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("code_max", "adc_bits", "bm", "bn", "interpret",
                     "out_dtype"),
)
def crossbar_mvm(
    x: jax.Array,      # (M, K)
    g_pos: jax.Array,  # (K, N) uint8
    g_neg: jax.Array,  # (K, N) uint8
    scale: jax.Array,  # (1, N) f32
    *,
    code_max: int = 255,
    adc_bits: int = 8,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
):
    """bk is pinned to the physical array height (RramConfig.array_rows =
    the K tile), so ADC behaviour is bit-accurate w.r.t. the compact
    model. K must be a multiple of 256."""
    bk = 256  # physical crossbar height
    m, k = x.shape
    _, n = g_pos.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(
            _kernel, n_k=n_k, code_max=code_max, adc_bits=adc_bits, rows=bk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, g_pos, g_neg, scale)
