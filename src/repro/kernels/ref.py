"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dora_linear_ref(x, g_pos, g_neg, scale, a, b, gamma, out_dtype=jnp.float32):
    """Y = (X @ ((G+-G-)*scale) + (X@A)@B) * gamma, all in f32."""
    xf = x.astype(jnp.float32)
    w = (g_pos.astype(jnp.float32) - g_neg.astype(jnp.float32)) * scale
    y = xf @ w
    y = y + (xf @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    return (y * gamma).astype(out_dtype)


def crossbar_mvm_ref(
    x, g_pos, g_neg, scale, *, code_max=255, adc_bits=8, bm=128, rows=256,
    out_dtype=jnp.float32,
):
    """Tile-accurate oracle for kernels/crossbar_mvm.py: same (bm x rows)
    tiling, per-tile DAC reference and saturating ADC."""
    m, k = x.shape
    n = g_pos.shape[1]
    assert m % bm == 0 and k % rows == 0
    adc_max = 2.0 ** (adc_bits - 1) - 1.0
    out = jnp.zeros((m, n), jnp.float32)
    for i in range(m // bm):
        acc = jnp.zeros((bm, n), jnp.float32)
        xs_m = x[i * bm : (i + 1) * bm].astype(jnp.float32)
        for kk in range(k // rows):
            xs = xs_m[:, kk * rows : (kk + 1) * rows]
            gp = g_pos[kk * rows : (kk + 1) * rows].astype(jnp.float32)
            gn = g_neg[kk * rows : (kk + 1) * rows].astype(jnp.float32)
            cur = xs @ (gp - gn)
            x_absmax = jnp.maximum(jnp.max(jnp.abs(xs)), 1e-8)
            step = rows * code_max * x_absmax / (adc_max * 16.0)
            cur = jnp.clip(jnp.round(cur / step), -adc_max, adc_max) * step
            acc = acc + cur
        out = out.at[i * bm : (i + 1) * bm].set(acc)
    return (out * scale).astype(out_dtype)


def selective_scan_ref(x, dt, a_log, b_sel, c_sel, d_skip, h0=None):
    """Sequential (step-by-step) selective-scan oracle in f64-ish f32.
    Shapes: x/dt (B,S,D), a_log (D,N), b_sel/c_sel (B,S,N)."""
    bsz, s, d = x.shape
    n = a_log.shape[-1]
    neg_a = -jnp.exp(a_log.astype(jnp.float32))
    h = jnp.zeros((bsz, d, n), jnp.float32) if h0 is None else h0
    ys = []
    for t in range(s):
        dt_t = dt[:, t].astype(jnp.float32)
        x_t = x[:, t].astype(jnp.float32)
        a_t = jnp.exp(dt_t[..., None] * neg_a[None])
        b_t = (dt_t * x_t)[..., None] * b_sel[:, t, None, :].astype(jnp.float32)
        h = a_t * h + b_t
        y = jnp.einsum("bdn,bn->bd", h, c_sel[:, t].astype(jnp.float32))
        ys.append(y + x_t * d_skip[None].astype(jnp.float32))
    return jnp.stack(ys, axis=1), h
