"""Backward-compat shim: the execution wrappers moved to
``repro/substrate/exec.py`` (the substrate layer owns backend dispatch;
kernels/ keeps only the Pallas kernel bodies and their oracles)."""
from repro.substrate.exec import (  # noqa: F401
    _pad_to,
    default_interpret,
    dora_gamma,
    rimc_linear,
    rimc_mvm_adc,
)
