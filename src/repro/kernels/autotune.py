"""Analytic block-size tuner for the fused crossbar kernels.

Replaces the hardcoded 128s in ``substrate/exec.py``: given the true
problem shape ``(M, K, N, r)`` the tuner picks ``(bm, bn, bk)`` and the
padded operand extents, driven by the same hardware constants the
roofline planner uses (``launch/roofline.py``):

* The MXU ridge point is ``PEAK_FLOPS / HBM_BW`` (~240 flop/byte on
  v5e). A decode call streams 2 bytes of codes per weight and performs
  ``2*M`` flops per weight, so any M below ``ridge/2`` (~120 rows) is
  memory-bound — the tile choice there minimizes grid bookkeeping and
  streams the codes exactly once: a single sublane-aligned M block
  (the GEMV variant) with the largest ``(bk, bn)`` that fits VMEM.
* At prefill shapes (M >= 128) the kernel is compute-bound and tiles at
  the 128x128 MXU granule; ``bk``/``bn`` still grow to the VMEM budget
  so each x tile is revisited as few times as possible.
* In interpret mode (CPU hosts) there is no hardware tile constraint, so
  the plan avoids padding entirely: blocks equal the true extents (grid
  collapses to the K split only for very large K). This is what makes
  the decode hot path on a CPU container do no ``jnp.pad`` work at all
  once operands are prepared (``substrate/prepared.py``).

Plans are memoized in a module-level table (``tile_table()``) — shape
dispatch at trace time is a dict lookup.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

from repro.launch.roofline import HBM_BW, PEAK_FLOPS

# flop/byte above which the MXU, not HBM, bounds the kernel
RIDGE_FLOPS_PER_BYTE = PEAK_FLOPS / HBM_BW

# VMEM working-set budget per grid cell: half of the 16 MiB/core so the
# pipeline can double-buffer the next block while computing.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# largest single M block the GEMV (single-M-block) variant handles; above
# this the tiled kernel's M grid takes over.
GEMV_MAX_M = 64

_LANE = 128      # minor-dim tile granule (all dtypes)
_SUBLANE_F32 = 8
_SUBLANE_I8 = 32


class TilePlan(NamedTuple):
    """Block sizes plus the padded operand extents they imply."""

    bm: int
    bn: int
    bk: int
    m_pad: int
    k_pad: int
    n_pad: int

    @property
    def gemv(self) -> bool:
        """Single M block (decode-shaped): dispatch the GEMV variant."""
        return self.m_pad == self.bm


_TABLE: Dict[Tuple, TilePlan] = {}


def _round_up(x: int, mult: int) -> int:
    return x + (-x) % mult


def _largest_divisor(dim: int, cap: int, granule: int) -> int:
    """Largest multiple of ``granule`` that divides ``dim`` and is <= cap
    (``dim`` itself is a multiple of ``granule``)."""
    best = granule
    d = granule
    while d <= min(dim, cap):
        if dim % d == 0:
            best = d
        d += granule
    return best


def _vmem_bytes(bm: int, bn: int, bk: int, r: int, int8: bool) -> int:
    """Per-grid-cell working set of the fused dora_linear kernel."""
    x_b = (1 if int8 else 4) * bm * bk
    codes_b = 2 * bk * bn  # g_pos + g_neg, 1 byte each
    acc_b = 4 * bm * bn
    xa_b = 4 * bm * r + 4 * bk * r + 4 * r * bn  # xa scratch + a + b tiles
    epilogue_b = 3 * 4 * bn + 4 * bm  # scale + gamma + out row, x row-scale
    return x_b + codes_b + acc_b + xa_b + epilogue_b


def select_tiles(
    m: int, k: int, n: int, r: int, *,
    interpret: bool = True, int8: bool = False,
) -> TilePlan:
    """Pick ``(bm, bn, bk)`` + padded extents for a ``(M, K, N, r)`` fused
    crossbar linear. Memoized — see module docstring for the policy."""
    key = (m, k, n, r, interpret, int8)
    plan = _TABLE.get(key)
    if plan is not None:
        return plan

    if interpret:
        # CPU functional mode: no tile alignment, so never pad. Split only
        # K (accumulator reduction keeps the working set bounded) when it
        # is very large and splits evenly; the grid stays 1x1 otherwise.
        bm, bn, bk = m, n, k
        if k > 2048:
            for cand in range(2048, 0, -1):
                if k % cand == 0:
                    bk = cand
                    break
        plan = TilePlan(bm, bn, bk, m, k, n)
    else:
        sublane = _SUBLANE_I8 if int8 else _SUBLANE_F32
        k_pad = _round_up(k, _LANE)
        n_pad = _round_up(n, _LANE)
        if m <= GEMV_MAX_M:
            # memory-bound region (M << ridge/2): one sublane-aligned M
            # block, codes streamed once through the K-parallel grid.
            bm = _round_up(m, sublane)
            m_pad = bm
        else:
            bm = _LANE
            m_pad = _round_up(m, _LANE)
        # grow bk first (fewer accumulator round-trips), then bn, while
        # the working set fits the double-buffered VMEM budget.
        bk = _largest_divisor(k_pad, 512, _LANE)
        while bk > _LANE and _vmem_bytes(bm, _LANE, bk, r, int8) > VMEM_BUDGET_BYTES:
            bk -= _LANE
        bn = _largest_divisor(n_pad, 512, _LANE)
        while bn > _LANE and _vmem_bytes(bm, bn, bk, r, int8) > VMEM_BUDGET_BYTES:
            bn -= _LANE
        plan = TilePlan(bm, bn, bk, m_pad, k_pad, n_pad)

    _TABLE[key] = plan
    return plan


def tile_table() -> Dict[Tuple, TilePlan]:
    """Snapshot of the memoized plan table (introspection/benchmarks)."""
    return dict(_TABLE)
