"""Fleet subsystem: N chips, one model, batched pytrees.

    from repro.fleet import Fleet, RecalibrationScheduler

    fleet = Fleet.program(cfg, key=0, n_chips=64, backend="codes")
    fleet.advance([6 * (i % 5) for i in range(64)])   # heterogeneous aging
    sched = RecalibrationScheduler(fleet, threshold=0.02,
                                   calib_args={"steps": 8})
    report = sched.run([24.0] * 12)    # a year of maintenance ticks
    print(report.summary())            # recalibrations avoided vs naive
    session = fleet.serve(chip=7)      # any chip, compiled steps shared

Chip ``i`` is bitwise an independent ``Deployment.program(cfg,
(fleet.teacher_key, fleet.chip_key(i)))`` at every point of its life —
the fleet is an execution strategy (one vmapped dispatch, one teacher
trace, one compile), not a different model.
"""
from repro.fleet.fleet import (  # noqa: F401
    Fleet,
    FleetCalibrationReport,
    chip_axes,
    chip_keys,
    fleet_compile_count,
    fleet_program_model,
)
from repro.fleet.scheduler import (  # noqa: F401
    FleetReport,
    RecalibrationScheduler,
    TickRecord,
)
