"""``Fleet`` — N chips, one model, batched pytrees.

The paper's deployment story at scale: every edge device carries the
SAME target weights but its own programming noise and its own
conductance-drift trajectory, and each must be restored with a tiny
per-chip SRAM adapter rather than RRAM rewrites. ``Deployment`` models
one such chip; ``Fleet`` models N of them as *batched* pytrees with a
leading chip axis — not N Python-level deployments:

* ``Fleet.program(cfg, key, n_chips)`` — ONE stacked programming event:
  every RRAM leaf becomes a ``CrossbarWeight`` with a leading ``(N,
  ...)`` chip axis (``jax.vmap`` of ``calibrate.program_leaf`` over
  per-chip keys ``fold_in(program_key, chip)``), while digital
  peripherals (norms, embeddings) stay SHARED buffers. Bitwise
  identical per chip to N ``Deployment.program`` calls with the same
  keys.
* ``fleet.advance(hours, chips=...)`` — heterogeneous drift clocks:
  each chip keeps its own ordered event history; a tick re-drifts all
  affected chips in one vmapped dispatch over per-chip ``(key, sigma,
  event_index)``. Order-independent ACROSS chips (each chip's draws
  depend only on its own key and history), order-sensitive within one.
* ``fleet.calibrate(...)`` — ONE ``jax.vmap``-ed DoRA loop over
  ``make_cached_calib_step``: the teacher-feature cache is computed once
  and amortized across the whole fleet (calibrating 64 chips costs one
  teacher trace), and the jitted step compiles ONCE per fleet shape —
  zero per-chip retraces (``fleet_compile_count`` pins this).
* ``fleet.chip(i)`` / ``fleet.serve(i)`` — slice chip ``i`` back out as
  a plain ``Deployment`` (bitwise: views of the stacked state). Serving
  reuses the per-``(cfg, backend)`` compiled-step registry, so serving
  chip 47 after chip 0 compiles nothing.
* ``fleet.snapshot()`` / ``Fleet.restore()`` — the multi-GB stacked
  base is never stored; restore replays the programming event and every
  per-chip drift tick (round-robin over heterogeneous histories) to
  bitwise equality.

The drift-aware recalibration policy over a fleet lives in
``fleet/scheduler.py`` (``RecalibrationScheduler`` + ``FleetReport``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import substrate
from repro.checkpoint.manager import as_manager
from repro.core import calibrate as C
from repro.core import rram
from repro.core.calibrate import (
    CalibState,
    make_cached_calib_loss,
    make_cached_calib_step,
    make_calib_step,
    rram_bytes,
    sram_bytes,
    teacher_features,
)
from repro.deploy.deployment import (
    Deployment,
    _dequant_like,
    _key_pair,
    calibration_batch,
)
from repro.deploy import serving
from repro.models import transformer as T
from repro.optim.adam import AdamW, adamw_init, adamw_update
from repro.optim.compress import allreduce_compressed

Pytree = Any

_FLEET_META = "fleet.json"


# ---------------------------------------------------------------------------
# stacked-pytree helpers: RRAM leaves carry the chip axis, peripherals are
# shared buffers — the same split program_model draws between RRAM and
# digital leaves.
# ---------------------------------------------------------------------------


def _is_cw(n) -> bool:
    return isinstance(n, rram.CrossbarWeight)


def chip_axes(tree: Pytree) -> Pytree:
    """Per-leaf vmap axis spec for a fleet-stacked base tree: ``0`` for
    RRAM leaves (``CrossbarWeight`` or their float read-backs), ``None``
    for shared digital peripherals. Usable as a ``jax.vmap``
    in/out_axes prefix."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: 0 if (_is_cw(x) or C._is_rram_leaf(p)) else None,
        tree, is_leaf=_is_cw,
    )


def _take(tree: Pytree, idx) -> Pytree:
    """Gather chips ``idx`` (int array -> keeps the chip axis; python int
    -> drops it) out of a fleet-stacked base tree."""

    def leaf(p, x):
        if _is_cw(x):
            return rram.CrossbarWeight(x.g_pos[idx], x.g_neg[idx], x.scale[idx])
        if C._is_rram_leaf(p):
            return x[idx]
        return x

    return jax.tree_util.tree_map_with_path(leaf, tree, is_leaf=_is_cw)


def _put(tree: Pytree, idx, sub: Pytree) -> Pytree:
    """Scatter the gathered-chips subtree ``sub`` back into the stacked
    tree at rows ``idx``; shared peripherals pass through untouched."""

    def leaf(p, x, s):
        if _is_cw(x):
            return rram.CrossbarWeight(
                x.g_pos.at[idx].set(s.g_pos),
                x.g_neg.at[idx].set(s.g_neg),
                x.scale.at[idx].set(s.scale),
            )
        if C._is_rram_leaf(p):
            return x.at[idx].set(s)
        return x

    return jax.tree_util.tree_map_with_path(leaf, tree, sub, is_leaf=_is_cw)


def fleet_program_model(
    base: Pytree, cfg: rram.RramConfig, chip_keys: jax.Array,
    *, mode: str = "codes",
) -> Pytree:
    """``program_model`` for a whole fleet in one stacked draw: every
    RRAM leaf is programmed under ``jax.vmap`` over the per-chip keys
    (per-leaf crc32 path fold exactly as the single-chip path), so chip
    ``i``'s codes are bitwise ``program_model(base, cfg, chip_keys[i])``.
    Digital peripherals are returned as the SAME buffers — the fleet
    shares one copy of norms/embeddings across all chips."""

    def leaf(path, x):
        if not C._is_rram_leaf(path):
            return x
        h = jnp.uint32(zlib.crc32(C._path_str(path).encode()))
        return jax.vmap(
            lambda ck: C.program_leaf(
                x, cfg, jax.random.fold_in(ck, h), mode=mode
            )
        )(chip_keys)

    return jax.tree_util.tree_map_with_path(leaf, base)


# ---------------------------------------------------------------------------
# compiled-step registry (mirrors deploy/serving.py): ONE jitted vmapped
# step per (kind, cfg, opt, trace backend) — the fleet-size axis is a
# shape handled by jax.jit's argument cache on the SAME callable, which
# is exactly what makes "calibrate 64 chips" cost one compile, not 64.
# ---------------------------------------------------------------------------

_FLEET_STEPS: Dict[Tuple, Any] = {}


def _registry_get(key: Tuple, build):
    fn = _FLEET_STEPS.get(key)
    if fn is None:
        fn = _FLEET_STEPS[key] = build()
    return fn


def fleet_compile_count(cfg) -> int:
    """Total compiled-computation count across this cfg's fleet step
    functions (any kind, any backend). Flat across chips and repeated
    same-shape calibrations — the per-chip-retrace regression counter
    (``benchmarks/fleet_bench.py`` fails if a second same-size
    calibration grows it)."""
    total = 0
    for key, fn in _FLEET_STEPS.items():
        if key[1] != cfg:
            continue
        size = getattr(fn, "_cache_size", None)
        total += size() if callable(size) else 0
    return total


def _calib_step_fn(cfg, opt: AdamW, kind: str, axes: Pytree):
    """The jitted vmapped calibration step for ``(kind, cfg, opt, active
    backend)``: chip axis on student base / adapters / optimizer / step,
    teacher base and batch broadcast."""
    in_state = CalibState(None, axes, 0, 0, 0)
    out_state = CalibState(None, axes, 0, 0, 0)

    def build_cached():
        step = make_cached_calib_step(cfg, opt)
        return jax.jit(jax.vmap(
            step, in_axes=(in_state, None, None), out_axes=(out_state, 0)
        ))

    def build_full():
        step = make_calib_step(cfg, opt)
        return jax.jit(jax.vmap(
            step, in_axes=(in_state, None), out_axes=(out_state, 0)
        ))

    key = (kind, cfg, opt, substrate.active_backend_name())
    return _registry_get(key, build_cached if kind == "cached" else build_full)


def _axes_to_specs(axes_tree: Pytree) -> Pytree:
    """Chip-axis prefix tree (0/None per chip_axes) -> PartitionSpec
    prefix tree over the "data" mesh axis: chip-stacked leaves shard
    their leading dim, shared peripherals replicate."""
    return jax.tree_util.tree_map(
        lambda a: P("data") if a == 0 else P(),
        axes_tree, is_leaf=lambda v: v is None,
    )


def _fleet_state_shardings(state: CalibState, axes: Pytree, mesh) -> CalibState:
    """NamedSharding tree matching a gathered CalibState: chip-axis
    leaves distribute over "data", the teacher and shared peripherals
    replicate — the placement under which the ordinary vmapped step is
    bitwise the single-device run (chips are independent rows)."""
    rep = NamedSharding(mesh, P())
    dat = NamedSharding(mesh, P("data"))

    def base_leaf(x, a):
        ns = dat if a == 0 else rep
        if _is_cw(x):
            return rram.CrossbarWeight(ns, ns, ns)
        return ns

    return CalibState(
        jax.tree_util.tree_map(lambda x: rep, state.teacher_base),
        jax.tree_util.tree_map(
            base_leaf, state.student_base, axes, is_leaf=_is_cw
        ),
        jax.tree_util.tree_map(lambda x: dat, state.adapters),
        jax.tree_util.tree_map(lambda x: dat, state.opt_state),
        dat,
    )


def _mesh_calib_step_fn(cfg, opt: AdamW, axes: Pytree, mesh):
    """The compressed-gradient mesh calibration step: ONE shard_map over
    the "data" axis, each device advancing its local block of chips
    against the replicated teacher-feature cache.

    The cross-device reduction is where ``optim.compress`` plugs in:
    each device scatters its local per-chip adapter gradients into a
    zero canvas at its chip offset, and ``allreduce_compressed``
    (error-feedback int8) assembles the global per-chip gradient stack —
    a mean over devices whose contributions are disjoint blocks, undone
    by the ``* n_dev`` rescale. Each device then slices its own block
    back out and applies the optimizer locally, so the update trajectory
    differs from the exact run only by the int8 quantization error,
    which the per-device residual feeds back into the next step.
    Reported losses are assembled with an EXACT psum (pre-update, so
    they are comparable step-for-step against the dense path)."""
    loss_fn = make_cached_calib_loss(cfg)
    n_dev = int(mesh.shape["data"])
    state_specs = CalibState(P(), _axes_to_specs(axes), P("data"), P("data"), P("data"))

    def build():
        def body(state, feats, batch, residual):
            dev = jax.lax.axis_index("data")
            vg = jax.vmap(
                jax.value_and_grad(loss_fn), in_axes=(0, axes, None, None)
            )
            losses, grads = vg(
                state.adapters, state.student_base, feats, batch
            )
            n_local = losses.shape[0]

            def scatter(g):
                full = jnp.zeros(
                    (n_local * n_dev,) + g.shape[1:], jnp.float32
                )
                start = (dev * n_local,) + (0,) * (g.ndim - 1)
                return jax.lax.dynamic_update_slice(
                    full, g.astype(jnp.float32), start
                )

            loss_full = jax.lax.psum(scatter(losses), "data")
            res_local = jax.tree_util.tree_map(lambda r: r[0], residual)
            g_full = jax.tree_util.tree_map(scatter, grads)
            reduced, new_res = allreduce_compressed(
                g_full, res_local, "data"
            )

            def localize(g):
                start = (dev * n_local,) + (0,) * (g.ndim - 1)
                return jax.lax.dynamic_slice(
                    g * n_dev, start, (n_local,) + g.shape[1:]
                )

            g_local = jax.tree_util.tree_map(localize, reduced)
            new_adapters, new_opt = jax.vmap(
                lambda g, o, a_: adamw_update(g, o, a_, opt)
            )(g_local, state.opt_state, state.adapters)
            new_state = CalibState(
                state.teacher_base, state.student_base, new_adapters,
                new_opt, state.step + 1,
            )
            new_residual = jax.tree_util.tree_map(
                lambda r: r[None], new_res
            )
            return new_state, {"loss": loss_full}, new_residual

        sm = shard_map(
            body, mesh=mesh,
            in_specs=(state_specs, P(), P(), P("data")),
            out_specs=(state_specs, P(), P("data")),
            check_rep=False,
        )
        return jax.jit(sm)

    key = ("mesh_compressed", cfg, opt, substrate.active_backend_name(), mesh)
    return _registry_get(key, build)


def _logits_fn(cfg, axes: Pytree, use_adapters: bool):
    """Jitted vmapped student forward -> per-chip f32 logits."""

    def build():
        def one(base, adapters, batch):
            return T.forward(
                {"base": base, "adapters": adapters}, batch, cfg,
                use_adapters=use_adapters,
            ).astype(jnp.float32)

        return jax.jit(jax.vmap(
            one, in_axes=(axes, 0 if use_adapters else None, None)
        ))

    key = ("logits", cfg, use_adapters, substrate.active_backend_name())
    return _registry_get(key, build)


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetCalibrationReport:
    """Outcome of one batched ``Fleet.calibrate`` call."""

    chips: List[int]             # which chips this pass trained
    losses: np.ndarray           # (steps, len(chips)) per-step feature MSE
    epochs_run: int
    sram_bytes: int              # TOTAL fleet side-car bytes (all chips)
    sram_bytes_per_chip: int
    rram_bytes: int              # total resident code bytes across the fleet
    base_params: int             # per-chip logical base params
    adapter_params: int          # per-chip adapter params
    calibrated_fraction: float
    backend: str
    # registry warm-start accounting: which of ``chips`` were seeded
    # from a stable reference (parallel ``warm_sources`` names them)
    warm_started_chips: List[int] = dataclasses.field(default_factory=list)
    warm_sources: List[str] = dataclasses.field(default_factory=list)

    @property
    def initial_loss(self) -> np.ndarray:
        return self.losses[0]

    @property
    def final_loss(self) -> np.ndarray:
        return self.losses[-1]

    def summary(self) -> str:
        return (
            f"calibrated {len(self.chips)} chips x {self.epochs_run} epochs: "
            f"feature MSE {float(self.initial_loss.mean()):.6f} -> "
            f"{float(self.final_loss.mean()):.6f} (fleet mean) | "
            f"sram_bytes/chip={self.sram_bytes_per_chip} "
            f"({self.calibrated_fraction:.2%} of params) "
            f"backend={self.backend}"
        )


# ---------------------------------------------------------------------------
# the fleet
# ---------------------------------------------------------------------------


class Fleet:
    """N deployments of one model as batched pytrees. See module docstring.

    ``self.codes`` is the stacked ground truth (chip axis on every
    ``CrossbarWeight``); ``self.base`` is what batched forwards consume
    (the codes themselves, or the stacked float read-back under
    ``dequant``). Peripheral leaves are shared single buffers."""

    def __init__(
        self, cfg, backend: str, teacher_base: Pytree, codes: Pytree,
        adapters: Pytree, teacher_key: jax.Array, program_key: jax.Array,
        n_chips: int,
    ):
        if backend not in serving.BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; available: {serving.BACKENDS}"
            )
        if n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {n_chips}")
        self.cfg = cfg
        self.backend = backend
        self.teacher_base = teacher_base
        self.codes = codes
        self.adapters = adapters
        self.teacher_key = teacher_key
        self.program_key = program_key
        self.n_chips = int(n_chips)
        self.opt_state: Optional[Pytree] = None
        self.steps: List[int] = [0] * self.n_chips
        self.drift_hours: List[List[float]] = [[] for _ in range(self.n_chips)]
        # fault lifecycle: (spec, chips) events, and the composed
        # full-chip-axis map re-derived from them
        self.fault_events: List[Tuple[Any, Tuple[int, ...]]] = []
        self._fault_map = None
        self._refresh_base()
        self._proxy_ref = self._gamma_norms()

    # -- programming event ---------------------------------------------------

    @classmethod
    def program(
        cls, cfg, key=0, n_chips: int = 1, *, backend: str = "dequant",
    ) -> "Fleet":
        """One stacked programming event for ``n_chips`` devices sharing
        the teacher's target weights: chip ``i`` programs under
        ``fold_in(program_key, i)``, so ``Deployment.program(cfg,
        (teacher_key, fleet.chip_key(i)))`` reproduces chip ``i``
        bitwise. Adapters start identical across chips (the teacher
        init) and diverge only through per-chip calibration."""
        teacher_key, program_key = _key_pair(key)
        params = T.init_params(teacher_key, cfg)
        keys = chip_keys(program_key, n_chips)
        codes = fleet_program_model(params["base"], cfg.rram, keys)
        adapters = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * n_chips), params["adapters"]
        )
        return cls(
            cfg=cfg, backend=backend, teacher_base=params["base"],
            codes=codes, adapters=adapters, teacher_key=teacher_key,
            program_key=program_key, n_chips=n_chips,
        )

    def chip_key(self, i: int) -> jax.Array:
        """Chip ``i``'s programming key (``fold_in(program_key, i)``) —
        hand it to ``Deployment.program(cfg, (teacher_key, chip_key))``
        to rebuild that one chip independently."""
        return jax.random.fold_in(self.program_key, int(i))

    def _refresh_base(self):
        # stacked analogue of Deployment._refresh_base: pristine stacked
        # codes stay the drift clock's ground truth, consumers (batched
        # forwards, calibration, the drift/hard-fault proxies) read the
        # faulty view
        self.codes_view = substrate.faulted_codes(
            self.codes, self._fault_map, self.cfg.rram
        )
        if self.backend == "dequant":
            self.base = _dequant_like(self.codes_view, self.teacher_base)
        else:
            self.base = self.codes_view
        self._base_axes = chip_axes(self.base)
        self._codes_axes = chip_axes(self.codes)

    # -- heterogeneous drift clocks ------------------------------------------

    def field_hours(self, chip: int) -> float:
        """Chip ``chip``'s total elapsed field time."""
        return float(sum(self.drift_hours[chip]))

    def _chip_list(self, chips) -> List[int]:
        if chips is None:
            return list(range(self.n_chips))
        out = [int(c) for c in chips]
        if len(set(out)) != len(out):
            raise ValueError(f"duplicate chips in {out}")
        for c in out:
            if not 0 <= c < self.n_chips:
                raise ValueError(f"chip {c} out of range [0, {self.n_chips})")
        return out

    def advance(
        self, hours: Union[float, Sequence[float]], chips=None,
    ) -> "Fleet":
        """Let field time pass on a subset of chips (default: all).
        ``hours`` is a scalar (same tick everywhere) or a per-chip
        sequence matching ``chips``. Every affected chip draws its tick
        from ``(chip_key, event_index, variance increment over ITS own
        clock)`` — one batched dispatch, yet chip ``i``'s new codes are
        bitwise what ``Deployment.advance`` would produce, and advancing
        disjoint chips in any interleaving of calls commutes.

        ``hours=0`` entries are true no-ops (no event recorded);
        negative hours raise ``ValueError``."""
        chips = self._chip_list(chips)
        if isinstance(hours, (int, float)):
            hlist = [float(hours)] * len(chips)
        else:
            hlist = [float(h) for h in hours]
            if len(hlist) != len(chips):
                raise ValueError(
                    f"hours has {len(hlist)} entries for {len(chips)} chips"
                )
        for h in hlist:
            if h < 0:
                raise ValueError(
                    f"drift clock cannot run backwards (hours={h})"
                )
        active = [(c, h) for c, h in zip(chips, hlist) if h > 0]
        if not active:
            return self
        sigmas = [
            rram.drift_sigma_increment(self.cfg.rram, self.field_hours(c), h)
            for c, h in active
        ]
        events = [len(self.drift_hours[c]) for c, _ in active]
        # chips whose tick draws nothing (sigma == 0, e.g. relative_drift
        # 0) still age — the event is recorded but no noise is drawn,
        # exactly the single-chip early-out.
        live = [k for k, s in enumerate(sigmas) if s > 0.0]
        if live:
            idx = jnp.asarray([active[k][0] for k in live], jnp.int32)
            keys = chip_keys(self.program_key, None, idx=idx)
            sig = jnp.asarray([sigmas[k] for k in live], jnp.float32)
            ev = jnp.asarray([events[k] for k in live], jnp.uint32)
            sub = _take(self.codes, idx)
            drift = jax.vmap(
                lambda c, k, s, e: C.drift_model(
                    c, self.cfg.rram, k, sigma=s, event_index=e
                ),
                in_axes=(self._codes_axes, 0, 0, 0),
                out_axes=self._codes_axes,
            )
            new = drift(sub, keys, sig, ev)
            self.codes = _put(self.codes, idx, new)
            if self._fault_map is not None:
                # faulted fleet: re-derive the faulty view (stuck cells
                # must stay pinned over the freshly drifted codes)
                self._refresh_base()
            elif self.backend == "dequant":
                # refresh the read-back for the AFFECTED rows only — a
                # single-chip tick must not re-dequantize the whole fleet
                self.base = _put(
                    self.base, idx, _dequant_like(new, self.teacher_base)
                )
                self.codes_view = self.codes
            else:
                self.base = self.codes_view = self.codes
        for c, h in active:
            self.drift_hours[c].append(h)
        return self

    # -- fault injection -----------------------------------------------------

    def inject(self, faults, chips=None) -> "Fleet":
        """Inject device faults (a ``FaultSpec`` or a sequence) into
        ``chips`` (default: all) — a lifecycle event like drift,
        recorded for snapshot/restore replay. Each selected chip draws
        from ``fold_in(spec_key, chip)`` and non-selected chips get
        exact-identity map rows, so chip ``i``'s faulty view is bitwise
        what ``Deployment.inject(spec.for_chip(i))`` produces on the
        solo chip. Pristine stacked codes are untouched; the composed
        map re-applies at read-back, so stuck cells stay pinned through
        ``advance`` and repeat injection is a no-op."""
        specs = list(faults) if isinstance(faults, (list, tuple)) else [faults]
        chip_list = tuple(self._chip_list(chips))
        for spec in specs:
            self.fault_events.append((spec, chip_list))
        self._rebuild_fault_map()
        self._refresh_base()
        return self

    def _rebuild_fault_map(self):
        from repro.faults import build_fleet_map, compose_maps

        if not self.fault_events:
            self._fault_map = None
            return
        per_chip = _take(self.codes, 0)  # per-chip leaf shapes template
        self._fault_map = compose_maps(
            build_fleet_map(per_chip, spec, self.cfg.rram, chips, self.n_chips)
            for spec, chips in self.fault_events
        )

    # -- batched calibration -------------------------------------------------

    def calibrate(
        self, batch_or_samples: Union[Dict, int] = 10, *,
        steps: int = 20, lr: float = 1e-3, opt: Optional[AdamW] = None,
        seq_len: int = 32, chips=None, cached_teacher: Optional[bool] = None,
        loss_threshold: float = 0.0, registry=None,
        warm_start: bool = False, record: bool = True,
        mesh: Optional[Mesh] = None, grad_compress: bool = False,
    ) -> FleetCalibrationReport:
        """Algorithm 1 for ``chips`` (default: all) as ONE vmapped loop:
        the frozen teacher's features are computed once and shared by
        every chip (the per-chip teacher forward is amortized away), and
        each jitted step advances all selected chips' adapters together.
        Chip ``i``'s losses/adapters/optimizer are bitwise what an
        independent ``Deployment.calibrate`` with the same key and
        default arguments would produce.

        ``loss_threshold`` stops the shared loop early once EVERY
        selected chip's per-step loss is at or below it (the loop is one
        vmapped dispatch, so epochs are spent fleet-wide).

        Registry threading (``repro.registry``): ``warm_start=True``
        seeds all selected chips from their per-chip nearest stable
        references in one batched scatter before the loop
        (``registry/warmstart.seed_fleet``); ``record=True`` persists
        each chip's result as a versioned artifact under its own
        ``(cfg, backend, drift signature)`` key afterwards.

        Mesh parallelism: with ``mesh`` (axes ("data", ...)) the chip
        axis shards over the "data" axis — the one vmapped loop runs
        chip blocks on separate devices while the teacher-feature cache
        is broadcast once. Bitwise equal to the single-device run (chips
        are independent batch rows). ``grad_compress=True`` additionally
        routes the per-chip adapter gradients through the error-feedback
        int8 ``optim.compress.allreduce_compressed`` cross-device
        reduction (``_mesh_calib_step_fn``): losses stay exact, the
        adapter trajectory tracks the exact one within quantization
        tolerance. Requires the cached-teacher path and ``len(chips)``
        divisible by the data-axis size."""
        cfg = self.cfg
        opt = opt if opt is not None else AdamW(lr=lr)
        chips = self._chip_list(chips)
        idx = jnp.asarray(chips, jnp.int32)
        batch = calibration_batch(cfg, batch_or_samples, seq_len)
        use_cached = True if cached_teacher is None else bool(cached_teacher)
        if grad_compress and mesh is None:
            raise ValueError("grad_compress needs a mesh to reduce across")
        if mesh is not None:
            if not use_cached:
                raise ValueError(
                    "mesh fleet calibration runs the cached-teacher path; "
                    "pass cached_teacher=True (or leave it unset)"
                )
            n_dev = int(mesh.shape["data"])
            if len(chips) % n_dev:
                raise ValueError(
                    f"{len(chips)} selected chips do not divide over the "
                    f"data axis ({n_dev} devices); pad the chip selection"
                )
        if self.opt_state is None:
            self.opt_state = jax.vmap(adamw_init)(self.adapters)
        warm_recs = [None] * len(chips)
        if registry is not None and warm_start:
            from repro.registry.warmstart import seed_fleet

            warm_recs = seed_fleet(self, registry, chips)
        state = CalibState(
            self.teacher_base,
            _take(self.base, idx),
            jax.tree_util.tree_map(lambda x: x[idx], self.adapters),
            jax.tree_util.tree_map(lambda x: x[idx], self.opt_state),
            jnp.asarray([self.steps[c] for c in chips], jnp.int32),
        )
        backend_ctx = (
            substrate.use_backend("dequant")
            if self.backend != "dequant" else contextlib.nullcontext()
        )
        losses: List[np.ndarray] = []
        with backend_ctx:
            axes = self._base_axes
            if use_cached:
                feats = teacher_features(self.teacher_base, batch, cfg)
                if mesh is not None:
                    # chip-axis leaves distribute over "data"; the
                    # teacher features / batch broadcast ONCE (device_put
                    # here, not per step inside the loop)
                    rep = NamedSharding(mesh, P())
                    state = jax.device_put(
                        state, _fleet_state_shardings(state, axes, mesh)
                    )
                    feats = jax.device_put(feats, rep)
                    batch = jax.device_put(
                        batch, jax.tree_util.tree_map(lambda x: rep, batch)
                    )
                if grad_compress:
                    step_fn = _mesh_calib_step_fn(cfg, opt, axes, mesh)
                    res = {
                        "r": jax.device_put(
                            jax.tree_util.tree_map(
                                lambda x: jnp.zeros(
                                    (int(mesh.shape["data"]),) + x.shape,
                                    jnp.float32,
                                ),
                                state.adapters,
                            ),
                            NamedSharding(mesh, P("data")),
                        )
                    }

                    def run(s):
                        s2, metrics, res["r"] = step_fn(
                            s, feats, batch, res["r"]
                        )
                        return s2, metrics
                else:
                    step_fn = _calib_step_fn(cfg, opt, "cached", axes)
                    run = lambda s: step_fn(s, feats, batch)
            else:
                step_fn = _calib_step_fn(cfg, opt, "full", axes)
                run = lambda s: step_fn(s, batch)
            for _ in range(steps):
                state, metrics = run(state)
                losses.append(np.asarray(metrics["loss"], np.float32))
                if loss_threshold and bool(
                    np.all(losses[-1] <= loss_threshold)
                ):
                    break
        if mesh is not None:
            # pull the sharded result back before the host-side scatter
            state = jax.device_get(state)
        self.adapters = jax.tree_util.tree_map(
            lambda full, sub: full.at[idx].set(sub),
            self.adapters, state.adapters,
        )
        self.opt_state = jax.tree_util.tree_map(
            lambda full, sub: full.at[idx].set(sub),
            self.opt_state, state.opt_state,
        )
        new_steps = np.asarray(state.step)
        for j, c in enumerate(chips):
            self.steps[c] = int(new_steps[j])
        # recalibration resets the drift baseline for the chips it touched
        cur = self._gamma_norms()
        self._proxy_ref = [
            ref.at[idx].set(now[idx]) for ref, now in zip(self._proxy_ref, cur)
        ]
        n_base, n_adapters = T.count_params(
            {"base": self.teacher_base,
             "adapters": jax.tree_util.tree_map(lambda x: x[0], self.adapters)}
        )
        total_sram = sram_bytes(self.adapters)
        report = FleetCalibrationReport(
            chips=chips,
            losses=np.stack(losses),
            epochs_run=len(losses),
            sram_bytes=total_sram,
            sram_bytes_per_chip=total_sram // self.n_chips,
            rram_bytes=rram_bytes(self.codes),
            base_params=n_base,
            adapter_params=n_adapters,
            calibrated_fraction=n_adapters / max(n_base, 1),
            backend=self.backend,
            warm_started_chips=[
                c for c, r in zip(chips, warm_recs) if r is not None
            ],
            warm_sources=[
                r.name for r in warm_recs if r is not None
            ],
        )
        if registry is not None and record:
            self._record_artifacts(registry, report, warm_recs)
        return report

    def _record_artifacts(self, registry, report, warm_recs) -> None:
        """Persist each calibrated chip's run as its own versioned
        artifact (its drift signature differs per chip, so each files
        under — and is stability-checked against — its own key)."""
        from repro.deploy.deployment import CalibrationReport

        for j, c in enumerate(report.chips):
            rec = warm_recs[j] if j < len(warm_recs) else None
            chip_report = CalibrationReport(
                losses=[float(x) for x in report.losses[:, j]],
                epochs_run=report.epochs_run,
                sram_bytes=report.sram_bytes_per_chip,
                rram_bytes=report.rram_bytes // self.n_chips,
                base_params=report.base_params,
                adapter_params=report.adapter_params,
                calibrated_fraction=report.calibrated_fraction,
                backend=report.backend,
                drift_events=len(self.drift_hours[c]),
                warm_started=rec is not None,
                warm_source=None if rec is None else rec.name,
            )
            registry.record(
                self.cfg, self.backend, self.chip_signature(c),
                adapters=jax.tree_util.tree_map(
                    lambda x: x[c], self.adapters
                ),
                opt_state=jax.tree_util.tree_map(
                    lambda x: x[c], self.opt_state
                ),
                report=chip_report,
                extra_meta={"chip": int(c)},
            )

    def chip_signature(self, i: int) -> np.ndarray:
        """Chip ``i``'s registry signature (device feature from its
        per-chip programming key + its own drift/fault state) — what its
        calibration artifacts file under and warm-start lookups rank
        against."""
        from repro.registry.warmstart import drift_signature

        i = int(i)
        return drift_signature(
            self.cfg.rram, self.chip_key(i),
            field_hours=self.field_hours(i),
            drift_events=len(self.drift_hours[i]),
            fault_events=sum(
                1 for _, chips in self.fault_events if i in chips
            ),
        )

    def reset_adapters(self) -> "Fleet":
        """Discard every chip's SRAM side-cars back to the fresh
        (output-preserving) teacher init and clear the optimizer — the
        "calibrate from scratch" state a new process starts from. The
        stacked codes and per-chip drift clocks are untouched."""
        fresh = T.init_params(self.teacher_key, self.cfg)["adapters"]
        self.adapters = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * self.n_chips), fresh
        )
        self.opt_state = None
        self.steps = [0] * self.n_chips
        return self

    # -- drift proxy ---------------------------------------------------------

    def _gamma_norms(self) -> List[jax.Array]:
        out: List[jax.Array] = []

        def leaf(x):
            if _is_cw(x):
                out.append(substrate.code_column_norms(x))
            return x

        # norms read the FAULTY view: the proxies must see what the
        # forwards (and the merged DoRA γ) actually read back
        jax.tree_util.tree_map(leaf, self.codes_view, is_leaf=_is_cw)
        return out

    def drift_proxy(self) -> np.ndarray:
        """(n_chips,) forward-free drift signal: mean relative movement
        of per-layer code column norms since each chip's LAST
        calibration (or programming). Conductance relaxation perturbs
        exactly the norms the merged DoRA γ divides by, so this tracks
        how stale each chip's SRAM compensation has become — at the cost
        of a read-back reduction, no activations, no matmuls. The
        ``RecalibrationScheduler`` recalibrates a chip only when this
        crosses its threshold."""
        vals = []
        for now, ref in zip(self._gamma_norms(), self._proxy_ref):
            rel = jnp.abs(now - ref) / jnp.maximum(jnp.abs(ref), 1e-8)
            vals.append(jnp.mean(rel.reshape(self.n_chips, -1), axis=1))
        return np.asarray(jnp.mean(jnp.stack(vals), axis=0))

    def hard_fault_proxy(self) -> np.ndarray:
        """(n_chips,) hard-fault signal: MAX relative movement of any
        single code column norm since the chip's last calibration.

        Drift is a diffusion — per-column norm movement is small and
        DISTRIBUTED, so even the worst column moves only a few standard
        errors above the mean the drift proxy reads. Stuck/saturated/
        retention-hit cells instead slam individual columns (a cell
        pinned to LRS jumps that one column's norm by tens of percent),
        a localized jump drift alone cannot produce. The scheduler
        thresholds this separately to tell "drifted — recalibrate"
        from "hard-faulted — recalibrate harder and flag the chip"."""
        vals = []
        for now, ref in zip(self._gamma_norms(), self._proxy_ref):
            rel = jnp.abs(now - ref) / jnp.maximum(jnp.abs(ref), 1e-8)
            vals.append(jnp.max(rel.reshape(self.n_chips, -1), axis=1))
        return np.asarray(jnp.max(jnp.stack(vals), axis=0))

    def logit_mse(self, batch: Dict, *, use_adapters: bool = True) -> np.ndarray:
        """(n_chips,) teacher/student logit MSE — the fleet-wide
        degradation/recovery metric. One teacher forward, one vmapped
        student forward (codes-resident fleets read back through the
        differentiable ``dequant`` trace, like calibration)."""
        t = T.forward(
            {"base": self.teacher_base, "adapters": {}}, batch, self.cfg,
            use_adapters=False,
        ).astype(jnp.float32)
        backend_ctx = (
            substrate.use_backend("dequant")
            if self.backend != "dequant" else contextlib.nullcontext()
        )
        with backend_ctx:
            fn = _logits_fn(self.cfg, self._base_axes, use_adapters)
            s = fn(self.base, self.adapters if use_adapters else {}, batch)
        return np.asarray(jnp.mean((s - t[None]) ** 2, axis=tuple(range(1, s.ndim))))

    # -- per-chip extraction / serving ---------------------------------------

    def chip(self, i: int) -> Deployment:
        """Slice chip ``i`` back out as a plain ``Deployment`` (bitwise:
        the same codes/adapters/optimizer/history, chip axis dropped).
        The fleet and the extracted deployment do not alias mutable
        state — advancing one does not move the other."""
        i = int(i)
        if not 0 <= i < self.n_chips:
            raise ValueError(f"chip {i} out of range [0, {self.n_chips})")
        dep = Deployment(
            cfg=self.cfg, backend=self.backend,
            teacher_base=self.teacher_base,
            codes=_take(self.codes, i),
            adapters=jax.tree_util.tree_map(lambda x: x[i], self.adapters),
            teacher_key=self.teacher_key, program_key=self.chip_key(i),
        )
        dep.drift_hours = list(self.drift_hours[i])
        dep.step = int(self.steps[i])
        if self.opt_state is not None:
            dep.opt_state = jax.tree_util.tree_map(
                lambda x: x[i], self.opt_state
            )
        # replay this chip's fault events with the chip index folded in —
        # bitwise the fleet map's row i by the shared per-chip keying
        specs = [
            spec.for_chip(i) for spec, chips in self.fault_events
            if i in chips
        ]
        if specs:
            dep.inject(specs)
        return dep

    def serve(self, chip: int) -> serving.ServeSession:
        """Serve chip ``chip``. Sessions share the per-``(cfg,
        backend)`` compiled-step registry, so serving the whole fleet
        chip-by-chip compiles the decode stack once, not N times."""
        return self.chip(chip).serve()

    # -- accounting ----------------------------------------------------------

    def sram_bytes(self) -> int:
        """Total SRAM side-car bytes across the fleet (N x per-chip)."""
        return sram_bytes(self.adapters)

    def rram_bytes(self) -> int:
        """Total resident code bytes across the fleet."""
        return rram_bytes(self.codes)

    # -- persistence ---------------------------------------------------------

    def snapshot(self, directory_or_manager, *, blocking: bool = True) -> int:
        """Checkpoint the fleet's mutable state: stacked adapters +
        optimizer, per-chip lifecycle records (keys, heterogeneous drift
        histories, step counters) and the drift-proxy baselines. The
        stacked base is NOT stored — restore replays programming and
        every per-chip drift tick."""
        manager = as_manager(directory_or_manager)
        if self.opt_state is None:
            self.opt_state = jax.vmap(adamw_init)(self.adapters)
        # a key that grows with ANY state change (calibration steps OR
        # drift events on any chip) — max(steps) alone stays flat across
        # drift-only maintenance ticks and would silently overwrite the
        # previous snapshot directory
        counts = [len(h) for h in self.drift_hours]
        step = int(sum(self.steps) + sum(counts))
        width = max(counts) if counts else 0
        padded = np.zeros((self.n_chips, width), np.float64)
        for c, hs in enumerate(self.drift_hours):
            padded[c, : len(hs)] = hs
        lifecycle = {
            "teacher_key": np.asarray(self.teacher_key),
            "program_key": np.asarray(self.program_key),
            "steps": np.asarray(self.steps, np.int64),
            "drift_hours": padded,
            "drift_counts": np.asarray(counts, np.int64),
        }
        manager.save(
            step,
            {"adapters": self.adapters, "opt": self.opt_state,
             "lifecycle": lifecycle, "proxy_ref": list(self._proxy_ref)},
            blocking=blocking,
        )
        meta = {
            "format": 1, "backend": self.backend,
            "arch": getattr(self.cfg, "name", None),
            "n_chips": self.n_chips,
            "fault_events": [
                [spec.to_dict(), list(chips)]
                for spec, chips in self.fault_events
            ],
        }
        with open(os.path.join(manager.directory, _FLEET_META), "w") as f:
            json.dump(meta, f)
        return step

    @classmethod
    def restore(
        cls, cfg, directory, *, step: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> "Fleet":
        """Rebuild a fleet from a snapshot: re-program all chips from
        the recorded keys, replay every chip's drift history in its own
        order (heterogeneous histories replay round-robin — chip
        independence makes cross-chip order irrelevant), then load the
        stacked adapters/optimizer and proxy baselines. Bitwise equal to
        the snapshotted fleet."""
        manager = as_manager(directory)
        if step is None:
            step = manager.latest_step()
        if step is None:
            raise FileNotFoundError(f"no snapshots in {directory}")
        meta_path = os.path.join(manager.directory, _FLEET_META)
        meta = {}
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                meta = json.load(f)
        backend = backend or meta.get("backend", "dequant")
        life = manager.restore(
            step,
            {"lifecycle": {
                "teacher_key": np.zeros((2,), np.uint32),
                "program_key": np.zeros((2,), np.uint32),
                "steps": np.zeros((0,), np.int64),
                "drift_hours": np.zeros((0, 0), np.float64),
                "drift_counts": np.zeros((0,), np.int64),
            }},
        )["lifecycle"]
        n_chips = int(meta.get("n_chips", len(life["steps"])))
        fleet = cls.program(
            cfg, (life["teacher_key"], life["program_key"]),
            n_chips=n_chips, backend=backend,
        )
        counts = np.asarray(life["drift_counts"], np.int64)
        padded = np.asarray(life["drift_hours"], np.float64)
        for r in range(int(counts.max()) if counts.size else 0):
            chips = [c for c in range(n_chips) if counts[c] > r]
            fleet.advance([float(padded[c, r]) for c in chips], chips=chips)
        for event in meta.get("fault_events") or []:
            # commutes with drift replay: faults never touch the
            # pristine codes, the view re-derives after every event
            from repro.faults import FaultSpec

            spec_dict, chips = event
            fleet.inject(FaultSpec.from_dict(spec_dict), chips=chips)
        restored = manager.restore(
            step,
            {"adapters": fleet.adapters,
             "opt": jax.vmap(adamw_init)(fleet.adapters),
             "proxy_ref": fleet._gamma_norms()},
        )
        fleet.adapters = restored["adapters"]
        fleet.opt_state = restored["opt"]
        fleet._proxy_ref = [jnp.asarray(x) for x in restored["proxy_ref"]]
        fleet.steps = [int(s) for s in life["steps"]]
        return fleet


def chip_keys(
    program_key: jax.Array, n_chips: Optional[int], *, idx=None
) -> jax.Array:
    """Stacked per-chip programming keys ``fold_in(program_key, i)`` for
    ``i in range(n_chips)`` (or the explicit ``idx`` array)."""
    if idx is None:
        idx = jnp.arange(n_chips, dtype=jnp.uint32)
    return jax.vmap(lambda i: jax.random.fold_in(program_key, i))(
        jnp.asarray(idx, jnp.uint32)
    )
