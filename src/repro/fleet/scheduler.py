"""Drift-driven recalibration scheduling over a ``Fleet``.

A fixed-interval policy recalibrates every chip at every maintenance
tick whether it needs it or not. But drift is log-time (``rram.
drift_sigma``) and heterogeneous — a chip that just recalibrated, or one
that barely aged this tick, has nothing to recover. The
``RecalibrationScheduler`` advances the fleet's per-chip clocks, reads
the forward-free drift proxy (``Fleet.drift_proxy``: relative movement
of the code column norms the merged DoRA γ divides by), and triggers the
batched SRAM calibration ONLY for chips whose proxy crossed the
threshold.

``FleetReport`` carries the economics: recalibrations done vs. the naive
fixed-interval count (the avoided ones are pure savings — calibration is
SRAM-only, so this is compute/energy, not endurance), per-chip
loss/proxy, resident SRAM/RRAM bytes, and the paper's
``lifespan_calibrations`` accounting (Table I): even the *scheduled*
recalibrations never write the array, so lifetime stays endurance-bound
at SRAM's 1e16, not RRAM's 1e8.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import rram
from repro.fleet.fleet import Fleet, FleetCalibrationReport


@dataclasses.dataclass
class TickRecord:
    """One maintenance tick: what aged, what the proxies read, who was
    recalibrated on which path (empty lists: nobody crossed a
    threshold). ``hard_faulted`` chips took the hard-fault path
    (``hard_calib_args``); ``recalibrated`` lists the drift path only."""

    tick: int
    hours: List[float]            # per-chip elapsed hours this tick
    proxy: np.ndarray             # (n_chips,) drift proxy AFTER aging
    recalibrated: List[int]
    report: Optional[FleetCalibrationReport]
    hard_proxy: Optional[np.ndarray] = None   # (n_chips,) max-column jump
    hard_faulted: List[int] = dataclasses.field(default_factory=list)
    hard_report: Optional[FleetCalibrationReport] = None


@dataclasses.dataclass
class FleetReport:
    """Fleet-lifetime accounting emitted by the scheduler."""

    n_chips: int
    ticks: int
    threshold: float
    recalibrations: int              # proxy-triggered, summed over ticks
    naive_recalibrations: int        # fixed-interval: n_chips per tick
    recalibrations_avoided: int
    per_chip_recalibrations: List[int]
    per_chip_field_hours: List[float]
    per_chip_proxy: List[float]      # proxy at the last tick
    per_chip_loss: List[float]       # last calibration's final feature MSE
                                     # per chip (nan: never recalibrated)
    sram_bytes: int                  # fleet-total resident side-car bytes
    rram_bytes: int                  # fleet-total resident code bytes
    calib_samples: int
    calib_epochs: int
    # paper Table I: calibrations until the written storage wears out.
    # DoRA writes SRAM only, so even the scheduled recalibrations leave
    # lifetime at 1e16-endurance scale; backprop-on-RRAM would burn
    # array endurance with every one of them.
    sram_lifespan_calibrations: float
    rram_lifespan_calibrations: float
    # hard-fault accounting (non-ideality suite): drift-path vs
    # hard-fault-path recalibrations sum to ``recalibrations``;
    # ``hard_faulted_chips`` stay flagged for the fleet's lifetime —
    # DoRA recovers their accuracy without an RRAM rewrite, but the
    # damage is physical and the operator should schedule replacement.
    hard_threshold: Optional[float] = None
    drift_recalibrations: int = 0
    hard_recalibrations: int = 0
    per_chip_hard_recalibrations: List[int] = dataclasses.field(
        default_factory=list
    )
    hard_faulted_chips: List[int] = dataclasses.field(default_factory=list)
    per_chip_hard_proxy: List[float] = dataclasses.field(default_factory=list)
    # registry warm-start accounting (steps-to-converge economics): a
    # chip-epoch is one chip trained for one epoch; the budget is what
    # running every triggered recalibration to its full configured step
    # count would have spent, so ``calibration_epochs_saved`` is the
    # concrete convergence saving the warm-started references bought
    # (0 without a registry or a ``loss_threshold`` to converge against).
    warm_started_recalibrations: int = 0
    calibration_chip_epochs: int = 0
    calibration_chip_epoch_budget: int = 0
    calibration_epochs_saved: int = 0

    def summary(self) -> str:
        avoided_pct = (
            100.0 * self.recalibrations_avoided
            / max(self.naive_recalibrations, 1)
        )
        hard = (
            f" | hard-faulted chips {self.hard_faulted_chips} "
            f"({self.hard_recalibrations} hard-path recalibrations)"
            if self.hard_faulted_chips else ""
        )
        return (
            f"fleet of {self.n_chips}: {self.ticks} ticks, "
            f"{self.recalibrations} recalibrations "
            f"({self.recalibrations_avoided} avoided vs naive "
            f"fixed-interval = {avoided_pct:.0f}%){hard} | "
            f"sram_bytes={self.sram_bytes} rram_bytes={self.rram_bytes} | "
            f"lifespan: {self.sram_lifespan_calibrations:.2e} SRAM "
            f"calibrations vs {self.rram_lifespan_calibrations:.2e} "
            f"if backprop wrote RRAM"
        )

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2, sort_keys=True, default=float)


class RecalibrationScheduler:
    """Advance heterogeneous chip clocks; recalibrate only past-threshold
    chips. See module docstring.

    ``calib_args`` are forwarded to ``Fleet.calibrate`` for the
    triggered chips (``batch_or_samples``, ``steps``, ``lr``,
    ``seq_len``, ...).

    Hard-fault discrimination (``hard_threshold``): the scheduler also
    reads ``Fleet.hard_fault_proxy`` — the MAX single-column norm jump,
    a signature drift's distributed diffusion cannot produce — and
    routes chips crossing it down a separate path: recalibrate with
    ``hard_calib_args`` (default: ``calib_args`` with DOUBLE the steps —
    the stacked fleet shares one adapter shape, so the extra capacity
    comes from calibration effort, not a rank change) and flag the chip
    in ``FleetReport.hard_faulted_chips``. A hard-faulted chip is
    excluded from the drift path that tick. ``hard_threshold=None``
    disables the hard path entirely (legacy behaviour).

    ``mesh`` shards every triggered calibration over the mesh's "data"
    axis (``Fleet.calibrate(mesh=...)``); ``grad_compress`` additionally
    routes the cross-device adapter-gradient reduction through the
    int8 error-feedback collective. Ticks whose due-chip count does not
    divide over the data axis fall back to the single-device path for
    that call — correctness never depends on the mesh."""

    def __init__(
        self, fleet: Fleet, *, threshold: float,
        calib_args: Optional[Dict[str, Any]] = None,
        hard_threshold: Optional[float] = None,
        hard_calib_args: Optional[Dict[str, Any]] = None,
        registry=None, warm_start: bool = True,
        mesh=None, grad_compress: bool = False,
    ):
        if threshold <= 0:
            raise ValueError(f"threshold must be > 0, got {threshold}")
        if hard_threshold is not None and hard_threshold <= threshold:
            raise ValueError(
                f"hard_threshold ({hard_threshold}) must exceed the drift "
                f"threshold ({threshold}) — the hard signal is a max over "
                f"columns and dominates the mean the drift proxy reads"
            )
        self.fleet = fleet
        self.threshold = float(threshold)
        self.calib_args = dict(calib_args or {})
        self.hard_threshold = (
            None if hard_threshold is None else float(hard_threshold)
        )
        if hard_calib_args is None:
            hard_calib_args = dict(self.calib_args)
            hard_calib_args["steps"] = 2 * int(
                self.calib_args.get("steps", 20)
            )
        self.hard_calib_args = dict(hard_calib_args)
        # registry: both recalibration paths warm-start from (and record
        # back into) the versioned calibration registry when one is given
        self.registry = registry
        self.warm_start = bool(warm_start) and registry is not None
        self.mesh = mesh
        self.grad_compress = bool(grad_compress)
        self.history: List[TickRecord] = []
        self._last_loss = np.full(fleet.n_chips, np.nan, np.float64)
        self._per_chip_recals = [0] * fleet.n_chips
        self._per_chip_hard_recals = [0] * fleet.n_chips
        self._hard_flagged: set = set()
        self._warm_recals = 0
        self._chip_epochs = 0
        self._chip_epoch_budget = 0

    @property
    def ticks(self) -> int:
        return len(self.history)

    @property
    def recalibrations(self) -> int:
        """Total recalibrations, both paths."""
        return sum(self._per_chip_recals) + sum(self._per_chip_hard_recals)

    @property
    def naive_recalibrations(self) -> int:
        """What a fixed-interval policy would have spent by now: every
        chip recalibrated at every maintenance tick."""
        return self.ticks * self.fleet.n_chips

    def tick(
        self, hours: Union[float, Sequence[float]], chips=None,
    ) -> TickRecord:
        """One maintenance interval: age ``chips`` (default all) by
        ``hours`` (scalar or per-chip), read the proxies, and
        recalibrate exactly the chips whose proxy exceeds a threshold —
        hard-faulted chips down the hard path, merely drifted ones down
        the drift path, healthy ones not at all."""
        fleet = self.fleet
        fleet.advance(hours, chips=chips)
        chip_list = fleet._chip_list(chips)
        if isinstance(hours, (int, float)):
            hlist = [float(hours)] * len(chip_list)
        else:
            hlist = [float(h) for h in hours]
        per_chip_hours = [0.0] * fleet.n_chips
        for c, h in zip(chip_list, hlist):
            per_chip_hours[c] = h
        proxy = fleet.drift_proxy()
        hard_proxy = None
        hard_due: List[int] = []
        if self.hard_threshold is not None:
            hard_proxy = fleet.hard_fault_proxy()
            hard_due = [
                int(c) for c in np.flatnonzero(hard_proxy > self.hard_threshold)
            ]
        due = [
            int(c) for c in np.flatnonzero(proxy > self.threshold)
            if int(c) not in hard_due
        ]
        registry_args = (
            {"registry": self.registry, "warm_start": self.warm_start}
            if self.registry is not None else {}
        )
        report = None
        if due:
            report = fleet.calibrate(
                chips=due, **self.calib_args, **registry_args,
                **self._mesh_args(len(due)),
            )
            for j, c in enumerate(due):
                self._per_chip_recals[c] += 1
                self._last_loss[c] = float(report.final_loss[j])
            self._account_epochs(report, self.calib_args)
        hard_report = None
        if hard_due:
            hard_report = fleet.calibrate(
                chips=hard_due, **self.hard_calib_args, **registry_args,
                **self._mesh_args(len(hard_due)),
            )
            for j, c in enumerate(hard_due):
                self._per_chip_hard_recals[c] += 1
                self._last_loss[c] = float(hard_report.final_loss[j])
                self._hard_flagged.add(c)
            self._account_epochs(hard_report, self.hard_calib_args)
        record = TickRecord(
            tick=len(self.history), hours=per_chip_hours,
            proxy=proxy, recalibrated=due, report=report,
            hard_proxy=hard_proxy, hard_faulted=hard_due,
            hard_report=hard_report,
        )
        self.history.append(record)
        return record

    def _mesh_args(self, n_due: int) -> Dict[str, Any]:
        """Mesh kwargs for one triggered calibrate call, or empty when
        no mesh is configured / the due set doesn't divide over it."""
        if self.mesh is None:
            return {}
        if n_due % int(self.mesh.shape["data"]):
            return {}
        return {"mesh": self.mesh, "grad_compress": self.grad_compress}

    def _account_epochs(self, report, args: Dict[str, Any]) -> None:
        """Steps-to-converge accounting for one batched calibrate call:
        actual chip-epochs spent vs the full configured step budget (the
        two differ when ``loss_threshold`` stops a warm-started loop
        early)."""
        n = len(report.chips)
        self._chip_epochs += report.epochs_run * n
        self._chip_epoch_budget += int(args.get("steps", 20)) * n
        self._warm_recals += len(report.warm_started_chips)

    def run(
        self, schedule: Sequence[Union[float, Sequence[float]]],
    ) -> FleetReport:
        """Drive a whole maintenance timeline (one ``tick`` per entry;
        entries are scalar hours or per-chip sequences) and emit the
        final ``FleetReport``."""
        for hours in schedule:
            self.tick(hours)
        return self.report()

    def report(self) -> FleetReport:
        fleet = self.fleet
        samples = self.calib_args.get("batch_or_samples", 10)
        if isinstance(samples, dict):
            samples = int(next(iter(samples.values())).shape[0])
        epochs = int(self.calib_args.get("steps", 20))
        proxy = (
            self.history[-1].proxy if self.history else fleet.drift_proxy()
        )
        if self.hard_threshold is None:
            hard_proxy = [float("nan")] * fleet.n_chips
        elif self.history and self.history[-1].hard_proxy is not None:
            hard_proxy = [float(p) for p in self.history[-1].hard_proxy]
        else:
            hard_proxy = [float(p) for p in fleet.hard_fault_proxy()]
        return FleetReport(
            n_chips=fleet.n_chips,
            ticks=self.ticks,
            threshold=self.threshold,
            recalibrations=self.recalibrations,
            naive_recalibrations=self.naive_recalibrations,
            recalibrations_avoided=(
                self.naive_recalibrations - self.recalibrations
            ),
            per_chip_recalibrations=list(self._per_chip_recals),
            per_chip_field_hours=[
                fleet.field_hours(c) for c in range(fleet.n_chips)
            ],
            per_chip_proxy=[float(p) for p in proxy],
            per_chip_loss=[float(x) for x in self._last_loss],
            sram_bytes=fleet.sram_bytes(),
            rram_bytes=fleet.rram_bytes(),
            calib_samples=int(samples),
            calib_epochs=epochs,
            sram_lifespan_calibrations=rram.lifespan_calibrations(
                samples=int(samples), epochs=epochs, on_rram=False
            ),
            rram_lifespan_calibrations=rram.lifespan_calibrations(
                samples=int(samples), epochs=epochs, on_rram=True
            ),
            hard_threshold=self.hard_threshold,
            drift_recalibrations=sum(self._per_chip_recals),
            hard_recalibrations=sum(self._per_chip_hard_recals),
            per_chip_hard_recalibrations=list(self._per_chip_hard_recals),
            hard_faulted_chips=sorted(self._hard_flagged),
            per_chip_hard_proxy=hard_proxy,
            warm_started_recalibrations=self._warm_recals,
            calibration_chip_epochs=self._chip_epochs,
            calibration_chip_epoch_budget=self._chip_epoch_budget,
            calibration_epochs_saved=(
                self._chip_epoch_budget - self._chip_epochs
            ),
        )
