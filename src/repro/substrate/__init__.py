"""Unified crossbar substrate: one resident weight format, many
execution backends.

``CrossbarWeight`` (uint8 differential conductance codes + per-column
scale, ``core/rram.py``) is the substrate's resident weight format for
the *entire* model zoo — ``calibrate.program_model(mode="codes")``
returns it for every RRAM leaf (including stacked expert / scan-group
shapes), and every matmul dispatches through
``models/layers.py::linear`` to one of the registered backends here.

See ``substrate/backends.py`` for the backend contract and README.md
(ARCHITECTURE) for when each backend is selected.
"""
from repro.core.rram import CrossbarWeight, dequantize, program  # noqa: F401
from repro.substrate.backends import (  # noqa: F401
    Backend,
    DEFAULT_BACKEND,
    active_backend_key,
    active_backend_name,
    available_backends,
    crossbar_linear,
    get_backend,
    register_backend,
    use_backend,
)
from repro.substrate.exec import (  # noqa: F401
    code_column_norms,
    default_interpret,
    dora_gamma,
    faulted_codes,
    faulted_view,
    rimc_linear,
    rimc_mvm_adc,
)
from repro.substrate.prepared import (  # noqa: F401
    PreparedCrossbar,
    ShardedPrepared,
    fuse_crossbars,
    place_serve_params,
    prepare_base_for_serve,
    prepare_crossbar,
    prepared_ref_forward,
    rimc_linear_prepared,
    serve_param_specs,
    shard_prepared_for_serve,
    tp_column_allgather,
)
