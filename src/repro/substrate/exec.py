"""Jit'd execution wrappers around the Pallas kernels (absorbed from
``kernels/ops.py``; that module re-exports these names for
backward compatibility).

``rimc_linear`` is the deployment-path op: it takes a CrossbarWeight (the
programmed+drifted RRAM array), the DoRA adapter, and the merged column
norms, picks block sizes with the analytic tuner
(``kernels/autotune.py``), pads operands to the planned tiles, and
dispatches the fused kernel — the decode-shaped GEMV variant when the
whole (small) M fits one block, the tiled kernel otherwise. On a CPU
host ``interpret=True`` executes the kernel body with jnp semantics (and
the tuner plans unpadded tiles); on TPU the same call compiles to
Mosaic. The serving hot path hoists the static operand padding out of
this per-call wrapper entirely — see ``substrate/prepared.py``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dora as dora_lib
from repro.core.rram import CrossbarWeight, dequantize
from repro.kernels import autotune
from repro.kernels.dora_linear import dora_linear, dora_linear_gemv
from repro.kernels.crossbar_mvm import crossbar_mvm


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def code_column_norms(xw: CrossbarWeight) -> jax.Array:
    """Per-output-column L2 norms of the resident codes, read back
    digitally: shape ``(..., n)`` for codes of shape ``(..., k, n)``.

    Stacked-codes dispatch: the reduction is over the row axis (-2), so
    ANY leading stacking works unchanged — a fleet's chip axis, expert
    stacks, scan-group stacks, or combinations. This is the cheap
    forward-free signal the fleet's drift proxy monitors: conductance
    relaxation perturbs the very column norms the DoRA merge (Algorithm
    2 line 12) divides by, so their relative movement since the last
    calibration tracks how stale the merged γ has become.
    """
    w = dequantize(xw)
    return jnp.sqrt(jnp.sum(w * w, axis=-2))


def faulted_view(xw: CrossbarWeight, leaf_faults, cfg) -> CrossbarWeight:
    """The faulty read-back view of one leaf's codes: retention decay,
    I-V read distortion, saturation clamps and stuck pins applied on the
    code grid (``repro/faults/map.py``), per-column scale untouched.

    This is the single-leaf read-back choke point of the non-ideality
    suite: the pristine resident codes are NEVER mutated — drift keeps
    operating on them — and every consumer (all three backends, the
    prepared/fused serve path, the fleet's drift proxy) reads the view
    this function derives, so backend parity under faults is bitwise by
    construction. ``leaf_faults=None`` is the healthy identity."""
    if leaf_faults is None:
        return xw
    return leaf_faults.apply(xw, cfg)


def faulted_codes(tree, fault_map, cfg):
    """Tree-level ``faulted_view``: derive the faulty codes view of a
    whole base tree through a composed ``FaultMap`` (``None`` = healthy,
    returns the tree unchanged). ``Deployment._refresh_base`` /
    ``Fleet._refresh_base`` call this after every programming, drift or
    injection event; ``prepare_base_for_serve(faults=...)`` routes the
    serve-time fast path through the same derivation."""
    if fault_map is None:
        return tree
    from repro.faults.map import apply_fault_map

    return apply_fault_map(tree, fault_map, cfg)


def dora_gamma(xw: CrossbarWeight, adapter: dict) -> jax.Array:
    """Merged DoRA scale M/||W_r + A@B|| (Algorithm 2 line 12), shape (1,N)."""
    w = dequantize(xw)
    norm = dora_lib.column_norm(w, adapter["lora_a"], adapter["lora_b"])
    m = adapter["dora_m"].astype(jnp.float32)
    return (m / norm)[None, :]


def rimc_linear(
    x: jax.Array,
    xw: CrossbarWeight,
    adapter: dict,
    gamma: Optional[jax.Array] = None,
    *,
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
    interpret: bool = True,
    accum: str = "f32",
) -> jax.Array:
    """Fused Y = gamma * (X W_r + (XA)B) with autotuned tile selection.
    x: (..., K) — leading dims flattened to M. Block sizes default to the
    analytic plan for (M, K, N, r) (``kernels/autotune.py``); explicit
    ``bm``/``bn``/``bk`` override it (operands pad up to any choice, so
    the output is block-size invariant — pinned by a hypothesis test).
    ``accum="int8"`` selects the integer MMA path."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = xw.g_pos.shape[-1]
    r = adapter["lora_a"].shape[-1]
    if gamma is None:
        gamma = dora_gamma(xw, adapter)
    xf = x.reshape(-1, k)
    m = xf.shape[0]
    if bm is None or bn is None or bk is None:
        plan = autotune.select_tiles(
            m, k, n, r, interpret=interpret, int8=(accum == "int8")
        )
        bm = plan.bm if bm is None else bm
        bn = plan.bn if bn is None else bn
        bk = plan.bk if bk is None else bk
    xf = _pad_to(_pad_to(xf, bm, 0), bk, 1)
    gp = _pad_to(_pad_to(xw.g_pos, bk, 0), bn, 1)
    gn = _pad_to(_pad_to(xw.g_neg, bk, 0), bn, 1)
    scale = _pad_to(xw.scale.reshape(1, -1).astype(jnp.float32), bn, 1)
    a = _pad_to(adapter["lora_a"].astype(jnp.float32), bk, 0)
    b = _pad_to(adapter["lora_b"].astype(jnp.float32), bn, 1)
    g = _pad_to(gamma.astype(jnp.float32), bn, 1)
    if xf.shape[0] == bm:
        # decode-shaped: single M block, K-parallel grid only
        y = dora_linear_gemv(
            xf, gp, gn, scale, a, b, g,
            bn=bn, bk=bk, interpret=interpret, accum=accum,
        )
    else:
        y = dora_linear(
            xf, gp, gn, scale, a, b, g,
            bm=bm, bn=bn, bk=bk, interpret=interpret, accum=accum,
        )
    return y[:m, :n].reshape(lead + (n,)).astype(x.dtype)


def rimc_mvm_adc(
    x: jax.Array,
    xw: CrossbarWeight,
    *,
    code_max: int = 255,
    adc_bits: int = 8,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """ADC-faithful crossbar MVM (no adapter): analog fidelity studies."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = xw.g_pos.shape[-1]
    xf = x.reshape(-1, k)
    m = xf.shape[0]
    xf = _pad_to(_pad_to(xf, bm, 0), 256, 1)
    gp = _pad_to(_pad_to(xw.g_pos, 256, 0), bn, 1)
    gn = _pad_to(_pad_to(xw.g_neg, 256, 0), bn, 1)
    scale = _pad_to(xw.scale.reshape(1, -1).astype(jnp.float32), bn, 1)
    y = crossbar_mvm(
        xf, gp, gn, scale, code_max=code_max, adc_bits=adc_bits,
        bm=bm, bn=bn, interpret=interpret,
    )
    return y[:m, :n].reshape(lead + (n,)).astype(x.dtype)
