"""Serve-time operand preparation for the codes fast path (ISSUE 6).

``substrate/exec.py::rimc_linear`` historically re-padded every static
operand (codes, per-column scale, LoRA A/B, merged gamma) to tile
multiples on every call — pure per-token overhead at decode shapes, and
one kernel launch per layer leaf on top. This module hoists all of that
to ``Deployment.serve()`` time:

* ``PreparedCrossbar`` — a registered pytree holding the tile-aligned
  codes plus the baked adapter operands (A, B, scale, merged gamma) and,
  optionally, the s8 offset-recode of the codes for the integer MMA
  path. The true (unpadded) ``k``/``n`` extents and the fused segment
  widths ride along as static aux data, so jit caching keys on them.
* ``prepare_crossbar`` / ``fuse_crossbars`` — build one prepared leaf
  from a single ``CrossbarWeight`` + adapter, or from several same-input
  leaves concatenated over N (gate+up, fused QKV, the MLA projection
  pairs). Fusion concatenates codes/scale/gamma over N, concatenates the
  LoRA A factors over r, and block-diagonalizes the B factors — exact
  math, one kernel launch instead of two or three.
* ``prepare_base_for_serve`` — walks a model base tree (with the merged
  adapters) and swaps every servable RRAM leaf for its prepared form,
  fusing where the model structure allows. The deployment's own
  ``codes``/``adapters`` trees are untouched — programming, drift and
  calibration keep the per-leaf layout.
* ``rimc_linear_prepared`` — the hot-path dispatch: per-call tensor work
  is ONLY the activation pad (nothing at all in interpret mode, where
  the autotuner plans unpadded tiles).

Fusion is structure-driven and conservative: only dict siblings that are
2-D/3-D ``{"w": CrossbarWeight}`` leaves with identical leading/K extents
fuse, and cross-attention (``xattn`` subtrees, where q reads the decoder
stream but k/v read the encoder) never fuses q/k/v. MoE expert stacks
(bare stacked ``CrossbarWeight`` values on the einsum path) pass through
unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.rram import CrossbarWeight
from repro.kernels import autotune
from repro.kernels.dora_linear import dora_linear, dora_linear_gemv, recode_s8
from repro.substrate import exec as X


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PreparedCrossbar:
    """Tile-aligned, adapter-baked serving form of one (possibly fused)
    RimcLinear. Arrays may carry leading stack dims (scan groups); the
    kernels consume the 2-D slices ``lax.scan`` produces."""

    g_pos: jax.Array          # (..., Kp, Np) u8, padded codes
    g_neg: jax.Array          # (..., Kp, Np) u8
    scale: jax.Array          # (..., 1, Np) f32 per-column code scale
    lora_a: jax.Array         # (..., Kp, R) f32 (R = sum of fused ranks)
    lora_b: jax.Array         # (..., R, Np) f32 (block-diagonal when fused)
    gamma: jax.Array          # (..., 1, Np) f32 merged DoRA magnitude
    k: int                    # true (unpadded) K
    n: int                    # true (unpadded) N total
    splits: Tuple[int, ...] = ()   # true per-segment N widths when fused
    g_pos_s8: Optional[jax.Array] = None  # offset recode for accum="int8"
    g_neg_s8: Optional[jax.Array] = None

    def tree_flatten(self):
        children = (self.g_pos, self.g_neg, self.scale, self.lora_a,
                    self.lora_b, self.gamma, self.g_pos_s8, self.g_neg_s8)
        return children, (self.k, self.n, self.splits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        gp, gn, scale, a, b, gamma, gp8, gn8 = children
        k, n, splits = aux
        return cls(gp, gn, scale, a, b, gamma, k, n, splits, gp8, gn8)


def _pad2(x: jax.Array, mult_k: int, mult_n: int) -> jax.Array:
    return X._pad_to(X._pad_to(x, mult_k, -2), mult_n, -1)


def serve_alignment(interpret: Optional[bool] = None) -> Tuple[int, int]:
    """(K, N) padding granules for prepared operands: none in interpret
    mode (the tuner plans unpadded tiles), the 128 lane granule on TPU."""
    if interpret is None:
        interpret = X.default_interpret()
    return (1, 1) if interpret else (128, 128)


def _operand_arrays(xw: CrossbarWeight, adapter: Optional[dict], acfg):
    """Unpadded (gp, gn, scale, a, b, gamma) for one leaf, adapters baked."""
    batch = xw.g_pos.shape[:-2]
    k, n = xw.g_pos.shape[-2:]
    adapter = adapter or {}
    if "lora_a" in adapter:
        a = adapter["lora_a"].astype(jnp.float32)
        b = adapter["lora_b"].astype(jnp.float32)
    else:
        a = jnp.zeros(batch + (k, 1), jnp.float32)
        b = jnp.zeros(batch + (1, n), jnp.float32)
    if "dora_m" in adapter:
        raise ValueError(
            "prepare expects merged adapters (merge_adapters_for_serve): "
            "got an unmerged dora_m"
        )
    if acfg.kind == "dora" and "dora_m_merged" in adapter:
        gamma = adapter["dora_m_merged"].astype(jnp.float32)[..., None, :]
    else:
        gamma = jnp.ones(batch + (1, n), jnp.float32)
    # xw.scale is already (..., 1, n) — broadcastable over rows
    scale = xw.scale.astype(jnp.float32)
    return xw.g_pos, xw.g_neg, scale, a, b, gamma


def _finish(gp, gn, scale, a, b, gamma, k, n, splits, align, int8):
    ak, an = align
    gp = _pad2(gp, ak, an)
    gn = _pad2(gn, ak, an)
    return PreparedCrossbar(
        g_pos=gp,
        g_neg=gn,
        scale=X._pad_to(scale, an, -1),
        lora_a=X._pad_to(a, ak, -2),
        lora_b=X._pad_to(b, an, -1),
        gamma=X._pad_to(gamma, an, -1),
        k=k, n=n, splits=splits,
        g_pos_s8=recode_s8(gp) if int8 else None,
        g_neg_s8=recode_s8(gn) if int8 else None,
    )


def prepare_crossbar(
    xw: CrossbarWeight, adapter: Optional[dict], acfg, *,
    align: Optional[Tuple[int, int]] = None, int8: bool = False,
) -> PreparedCrossbar:
    """One leaf -> its prepared serving form (no fusion)."""
    align = serve_alignment() if align is None else align
    gp, gn, scale, a, b, gamma = _operand_arrays(xw, adapter, acfg)
    k, n = xw.g_pos.shape[-2:]
    return _finish(gp, gn, scale, a, b, gamma, k, n, (n,), align, int8)


def fuse_crossbars(
    leaves: Sequence[Tuple[CrossbarWeight, Optional[dict]]], acfg, *,
    align: Optional[Tuple[int, int]] = None, int8: bool = False,
) -> PreparedCrossbar:
    """Fuse same-input leaves into one launch over concatenated N.

    Codes/scale/gamma concatenate along N; the LoRA A factors concatenate
    along r and the B factors become block-diagonal, so
    ``x @ A_cat @ B_blkdiag == concat_i(x @ A_i @ B_i)`` exactly."""
    align = serve_alignment() if align is None else align
    parts = [_operand_arrays(xw, ad, acfg) for xw, ad in leaves]
    k = leaves[0][0].g_pos.shape[-2]
    widths = tuple(xw.g_pos.shape[-1] for xw, _ in leaves)
    ranks = [p[3].shape[-1] for p in parts]
    r_total = sum(ranks)
    gp = jnp.concatenate([p[0] for p in parts], axis=-1)
    gn = jnp.concatenate([p[1] for p in parts], axis=-1)
    scale = jnp.concatenate([p[2] for p in parts], axis=-1)
    gamma = jnp.concatenate([p[5] for p in parts], axis=-1)
    a = jnp.concatenate([p[3] for p in parts], axis=-1)
    b_blocks = []
    off = 0
    for p, r in zip(parts, ranks):
        bi = p[4]
        widths_nd = [(0, 0)] * bi.ndim
        widths_nd[-2] = (off, r_total - off - r)
        b_blocks.append(jnp.pad(bi, widths_nd))
        off += r
    b = jnp.concatenate(b_blocks, axis=-1)
    return _finish(
        gp, gn, scale, a, b, gamma, k, sum(widths), widths, align, int8
    )


def prepared_ref_forward(x: jax.Array, prep: PreparedCrossbar) -> jax.Array:
    """Pure-jnp reference over a prepared leaf (true-extent slices): the
    ``dequant`` backend's view of a prepared tree, and the test oracle."""
    k, n = prep.k, prep.n
    gp = prep.g_pos[..., :k, :n].astype(jnp.float32)
    gn = prep.g_neg[..., :k, :n].astype(jnp.float32)
    w = (gp - gn) * prep.scale[..., :, :n]
    xf = x.astype(jnp.float32)
    y = xf @ w + (xf @ prep.lora_a[..., :k, :]) @ prep.lora_b[..., :, :n]
    return (y * prep.gamma[..., :, :n]).astype(x.dtype)


def rimc_linear_prepared(
    x: jax.Array, prep: PreparedCrossbar, *,
    bm: Optional[int] = None, bn: Optional[int] = None,
    bk: Optional[int] = None, interpret: bool = True, accum: str = "f32",
) -> jax.Array:
    """Hot-path fused linear over prepared operands: the only per-call
    tensor work besides the kernel is padding x (rows to the M block,
    cols to the prepared K) — a no-op in interpret mode."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    m = xf.shape[0]
    kp, npad = prep.g_pos.shape[-2:]
    r = prep.lora_a.shape[-1]
    plan = autotune.select_tiles(
        m, kp, npad, r, interpret=interpret, int8=(accum == "int8")
    )
    bm = plan.bm if bm is None else bm
    bn = plan.bn if bn is None else bn
    bk = plan.bk if bk is None else bk
    xf = X._pad_to(X._pad_to(xf, bm, 0), kp, 1)
    if accum == "int8" and prep.g_pos_s8 is not None:
        gp, gn = prep.g_pos_s8, prep.g_neg_s8
    else:
        gp, gn = prep.g_pos, prep.g_neg
    if xf.shape[0] == bm:
        y = dora_linear_gemv(
            xf, gp, gn, prep.scale, prep.lora_a, prep.lora_b, prep.gamma,
            bn=bn, bk=bk, interpret=interpret, accum=accum,
        )
    else:
        y = dora_linear(
            xf, gp, gn, prep.scale, prep.lora_a, prep.lora_b, prep.gamma,
            bm=bm, bn=bn, bk=bk, interpret=interpret, accum=accum,
        )
    return y[:m, :prep.n].reshape(lead + (prep.n,)).astype(x.dtype)


# ---------------------------------------------------------------------------
# model-tree preparation
# ---------------------------------------------------------------------------

# same-input sibling groups the walker fuses, in precedence order; a key
# consumed by one group is not considered again.
_FUSE_GROUPS = (
    ("_qkv", ("q", "k", "v")),          # self-attention (skipped under xattn)
    ("_q_kvd", ("q", "kv_down")),       # MLA: q + joint KV compression
    ("_kup_vup", ("k_up", "v_up")),     # MLA: latent -> K(nope) + V
    ("_gate_up", ("gate", "up")),       # gated MLP
)


def _servable(node) -> bool:
    """A dict leaf the serving kernels can take over: {"w": codes} with a
    2-D (plain) or 3-D (scan-stacked) code array. 4-D conv codes keep
    their dedicated path."""
    return (
        isinstance(node, dict)
        and isinstance(node.get("w"), CrossbarWeight)
        and node["w"].g_pos.ndim in (2, 3)
    )


def _fusable(b: dict, keys: Tuple[str, ...]) -> bool:
    if not all(_servable(b.get(key)) for key in keys):
        return False
    # identical leading/K extents (same input stream) and code dtypes
    lead_k = {b[key]["w"].g_pos.shape[:-1] for key in keys}
    return len(lead_k) == 1


def prepare_base_for_serve(
    base, adapters, cfg, *, int8: bool = False,
    align: Optional[Tuple[int, int]] = None, faults=None,
):
    """Swap every servable RRAM leaf of ``base`` for its
    ``PreparedCrossbar`` form, fusing same-input sibling leaves. The
    input trees are not mutated; ``adapters`` must be the merged tree
    (``merge_adapters_for_serve``) so gammas bake in exactly.

    ``faults`` (a composed ``FaultMap``) derives the faulty read-back
    view of ``base`` BEFORE any padding/fusion, so the prepared fast
    path serves bitwise the same faulty codes the raw backends read.
    ``Deployment.serve`` pre-applies its map (``self.base`` is already
    the faulty view); the parameter is for direct callers preparing a
    pristine tree."""
    acfg = cfg.adapter
    align = serve_alignment() if align is None else align
    if faults is not None:
        from repro.substrate.exec import faulted_codes

        base = faulted_codes(base, faults, cfg.rram)

    def walk(b, a, cross=False):
        if _servable(b):
            out = dict(b)
            out["w"] = prepare_crossbar(
                b["w"], a if isinstance(a, dict) else None, acfg,
                align=align, int8=int8,
            )
            return out
        if isinstance(b, dict):
            a_d = a if isinstance(a, dict) else {}
            out = {}
            consumed: set = set()
            for fused_key, keys in _FUSE_GROUPS:
                if consumed.intersection(keys):
                    continue
                if fused_key == "_qkv" and (cross or "kv_down" in b):
                    continue
                if _fusable(b, keys):
                    out[fused_key] = {"w": fuse_crossbars(
                        [(b[key]["w"], a_d.get(key)) for key in keys],
                        acfg, align=align, int8=int8,
                    )}
                    consumed.update(keys)
            for key, val in b.items():
                if key in consumed:
                    continue
                out[key] = walk(val, a_d.get(key), cross or key == "xattn")
            return out
        if isinstance(b, list):
            a_l = a if isinstance(a, (list, tuple)) else [None] * len(b)
            return [walk(v, a_l[i], cross) for i, v in enumerate(b)]
        return b

    return walk(base, adapters)


# ---------------------------------------------------------------------------
# tensor-parallel serving (ISSUE 9): column-sharded prepared leaves
# ---------------------------------------------------------------------------

_PREP_FIELDS = (
    "g_pos", "g_neg", "scale", "lora_a", "lora_b", "gamma",
    "g_pos_s8", "g_neg_s8",
)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedPrepared:
    """Column-parallel wrapper around one ``PreparedCrossbar``.

    Outside ``shard_map`` the inner arrays are the full global operands,
    placed with ``NamedSharding`` over the last dim; inside the decode
    step's ``shard_map`` body each device sees its ``n_total //
    mesh.shape[axis]`` column slice, which is exactly what the inner
    aux advertises (``local.n`` is the per-shard width). The backend
    runs the ordinary prepared kernel on the local slice and the DoRA
    epilogue finishes with ``tp_column_allgather`` — a zero-scatter +
    ``psum`` over ``axis`` that is bitwise-exact because every output
    column is produced by exactly one shard with the full K reduction.

    Only unpadded leaves whose true N divides the axis size are wrapped
    (see ``shard_prepared_for_serve``); everything else replicates,
    which is bitwise-safe by construction.
    """

    local: PreparedCrossbar   # aux (k, n, splits) describe the PER-SHARD view
    n_total: int
    axis: str = "model"

    def tree_flatten(self):
        return (self.local,), (self.n_total, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def tp_column_allgather(y: jax.Array, n_total: int, axis: str) -> jax.Array:
    """DoRA-epilogue collective: place the local column block of ``y``
    into a zero canvas at this shard's offset and ``psum`` over ``axis``.
    Disjoint blocks -> each output element is one shard's value plus
    exact zeros, so the result matches the unsharded kernel bitwise."""
    n_local = y.shape[-1]
    i = jax.lax.axis_index(axis)
    full = jnp.zeros(y.shape[:-1] + (n_total,), y.dtype)
    start = (0,) * (y.ndim - 1) + (i * n_local,)
    full = jax.lax.dynamic_update_slice(full, y, start)
    return jax.lax.psum(full, axis)


def _prep_like(prep: PreparedCrossbar, fn, aux=None) -> PreparedCrossbar:
    """A PreparedCrossbar whose array children are ``fn(name, child)``
    (None children pass through); aux defaults to ``prep``'s own, so the
    result has the same treedef — required for shard_map spec trees."""
    children, old_aux = prep.tree_flatten()
    new = tuple(
        None if c is None else fn(nm, c)
        for nm, c in zip(_PREP_FIELDS, children)
    )
    return PreparedCrossbar.tree_unflatten(aux or old_aux, new)


def shard_prepared_for_serve(params, mesh, *, tp: str = "model"):
    """Wrap every column-shardable ``PreparedCrossbar`` leaf of a serve
    params tree in ``ShardedPrepared``; return ``(params, stats)``.

    A leaf is shardable when its path matches a tensor-parallel rule in
    ``sharding.rules.PARAM_RULES`` ("T" anywhere in the spec — output-dim
    sharding of a linear is exact regardless of the rule's orientation,
    columns being independent), it carries no N padding (interpret-mode
    alignment), and its true N divides ``mesh.shape[tp]``. MoE expert
    stacks are never prepared leaves and therefore always replicate —
    their combine einsum reduces over E, so sharding E would reorder the
    accumulation and break bitwise parity.
    """
    from repro.sharding import rules as R

    size = int(mesh.shape[tp])
    stats = {"sharded": 0, "replicated": 0}

    def leaf(path, v):
        if not isinstance(v, PreparedCrossbar):
            return v
        p = R._path_str(path)
        ok = (
            size > 1
            and R.serve_tp_shardable(p)
            and v.g_pos.shape[-1] == v.n
            and v.n % size == 0
        )
        if not ok:
            stats["replicated"] += 1
            return v
        stats["sharded"] += 1
        n_local = v.n // size
        local = _prep_like(v, lambda nm, c: c, aux=(v.k, n_local, (n_local,)))
        return ShardedPrepared(local, v.n, tp)

    out = jax.tree_util.tree_map_with_path(
        leaf, params, is_leaf=lambda v: isinstance(v, PreparedCrossbar)
    )
    return out, stats


def serve_param_specs(params):
    """PartitionSpec tree matching ``params``' treedef: ``ShardedPrepared``
    wrappers shard their operands' last dim over their axis (lora_a is
    the K-side factor and replicates); everything else replicates."""

    def leaf(v):
        if isinstance(v, ShardedPrepared):
            def spec(nm, c):
                if nm == "lora_a":
                    return P()
                return P(*([None] * (c.ndim - 1) + [v.axis]))

            return ShardedPrepared(
                _prep_like(v.local, spec), v.n_total, v.axis
            )
        if isinstance(v, PreparedCrossbar):
            return _prep_like(v, lambda nm, c: P())
        return P()

    return jax.tree_util.tree_map(
        leaf, params,
        is_leaf=lambda v: isinstance(v, (ShardedPrepared, PreparedCrossbar)),
    )


def place_serve_params(params, mesh):
    """device_put the serve params tree onto ``mesh`` per
    ``serve_param_specs`` (sharded wrappers' operands land distributed,
    the rest replicated once per device)."""
    specs = serve_param_specs(params)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P),
    )
    return jax.device_put(params, shardings)
