"""Pluggable execution backends over resident crossbar codes.

A deployment programs the model once (``calibrate.program_model``); from
then on the RRAM array is a frozen uint8 ``(G+, G-, scale)`` triple that
is *never rewritten*. What varies is how a forward pass reads it:

  * ``codes``     — the deployment path. Codes stay resident (uint8 in
                    HBM); the fused ``dora_linear`` Pallas kernel
                    dequantizes in-register per tile and applies the
                    DoRA epilogue. ``interpret=True`` on CPU hosts.
  * ``dequant``   — read the array back to floats per call and run the
                    plain jnp path. Differentiable w.r.t. the adapters,
                    so calibration/training over a codes-resident
                    student uses this backend.
  * ``codes_adc`` — ADC-faithful ``crossbar_mvm`` kernel (saturating
                    ADC per 256-row tile) plus digital low-rank/DoRA
                    compensation. Fidelity studies.

The backend is selected per-deployment with the ``use_backend`` context
manager (read at trace time, so wrap the ``jax.jit`` trace in it) or
per-call via ``crossbar_linear(..., backend=...)``. Float weights never
reach this module — ``models/layers.py::linear`` dispatches here only
for ``CrossbarWeight`` leaves.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import dora as dora_lib
from repro.core.dora import AdapterConfig
from repro.core.rram import CrossbarWeight, RramConfig, dequantize
from repro.substrate import exec as X
from repro.substrate.prepared import (
    PreparedCrossbar,
    ShardedPrepared,
    prepared_ref_forward,
    rimc_linear_prepared,
    tp_column_allgather,
)

DEFAULT_BACKEND = "codes"

_REGISTRY: Dict[str, "Backend"] = {}
_ACTIVE = threading.local()


class Backend:
    """One way to execute Y = f(X, resident codes, adapter)."""

    name: str = "abstract"

    def linear(
        self,
        x: jax.Array,
        xw: CrossbarWeight,
        adapter: Optional[dict],
        acfg: AdapterConfig,
    ) -> jax.Array:
        raise NotImplementedError


def register_backend(cls):
    """Class decorator: instantiate and register under ``cls.name``."""
    _REGISTRY[cls.name] = cls()
    return cls


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown substrate backend {name!r}; "
            f"available: {available_backends()}"
        ) from None


def available_backends():
    return tuple(sorted(_REGISTRY))


@contextlib.contextmanager
def use_backend(name: str, **options):
    """Bind the ambient backend (plus backend-specific keyword
    ``options``, e.g. ``code_max``/``adc_bits`` for ``codes_adc``) for
    CrossbarWeight leaves.

    Backend choice is a Python-level (static) decision: it must be
    active while jit TRACES the function, not when the compiled
    function runs. CAUTION: the backend is NOT part of the jit cache
    key — calling one already-jitted function under two different
    ``use_backend`` scopes hits the first trace's cache and silently
    reuses its backend. Jit inside the scope (what launch/serve.py
    does by rebuilding its step lambdas per call), or thread the
    explicit ``backend=`` argument through ``layers.linear``."""
    get_backend(name)  # validate eagerly
    prev = getattr(_ACTIVE, "val", None)
    _ACTIVE.val = (name, options)
    try:
        yield
    finally:
        _ACTIVE.val = prev


def active_backend_name() -> str:
    val = getattr(_ACTIVE, "val", None)
    return val[0] if val else DEFAULT_BACKEND


def _active_options() -> dict:
    val = getattr(_ACTIVE, "val", None)
    return val[1] if val else {}


def active_backend_key() -> tuple:
    """Hashable (name, sorted options) identity of the ambient backend —
    what trace-level caches (the serving step registry) must key on,
    since the options change traced behaviour just like the name does
    (e.g. ``accum="int8"`` vs the f32 path)."""
    val = getattr(_ACTIVE, "val", None)
    name, options = val if val else (DEFAULT_BACKEND, {})
    return (name, tuple(sorted(options.items())))


def crossbar_linear(
    x: jax.Array,
    xw: CrossbarWeight,
    adapter: Optional[dict],
    acfg: AdapterConfig,
    *,
    backend: Optional[str] = None,
) -> jax.Array:
    """Execute one RimcLinear over resident codes via the selected
    backend. This is the choke point ``models/layers.py::linear``
    dispatches to whenever a base leaf is a ``CrossbarWeight``.

    An explicit ``backend=`` ignores the ambient scope (and its
    options); the ambient scope's options are forwarded to the
    backend's ``linear``."""
    if backend is not None:
        return get_backend(backend).linear(x, xw, adapter or {}, acfg)
    return get_backend(active_backend_name()).linear(
        x, xw, adapter or {}, acfg, **_active_options()
    )


# ---------------------------------------------------------------------------
# helpers shared by backends
# ---------------------------------------------------------------------------


def _gamma_for(xw: CrossbarWeight, adapter: dict, acfg) -> Optional[jax.Array]:
    """(1, N) DoRA epilogue scale for the fused kernel, or None for
    LoRA/no-adapter (identity epilogue)."""
    if not adapter or acfg.kind != "dora":
        return None
    if "dora_m_merged" in adapter:
        # Algorithm 2 line 12 already folded M/||W_r + A@B|| at deployment.
        return adapter["dora_m_merged"].astype(jnp.float32)[None, :]
    # unmerged (calibration-time) DoRA: the norm is a digital precompute —
    # it reads the codes back once, outside the MVM hot path.
    return X.dora_gamma(xw, adapter)


def _zero_adapter(k: int, n: int) -> dict:
    """Rank-1 all-zero side-car: lets the fused kernel serve layers that
    have no adapter (pure-RRAM teacher path) without a second kernel."""
    return {
        "lora_a": jnp.zeros((k, 1), jnp.float32),
        "lora_b": jnp.zeros((1, n), jnp.float32),
    }


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


@register_backend
class DequantBackend(Backend):
    """Read codes back to floats per call; plain jnp forward. This is the
    only differentiable-path backend (gradients flow to the adapter; the
    uint8 codes are constants), so calibration over a codes-resident
    student runs under ``use_backend('dequant')``."""

    name = "dequant"

    def linear(self, x, xw, adapter, acfg):
        if isinstance(xw, ShardedPrepared):
            raise TypeError(
                "dequant reads full-extent prepared leaves; a sharded "
                "serve tree only executes inside the codes backend's "
                "shard_map decode step"
            )
        if isinstance(xw, PreparedCrossbar):
            # prepared trees bake their adapters in; the float view is
            # the true-extent reference forward
            return prepared_ref_forward(x, xw)
        w = dequantize(xw)
        return dora_lib.adapted_forward(x, w, adapter, acfg)


@register_backend
class CodesBackend(Backend):
    """Deployment path: fused Pallas kernel over resident uint8 codes.
    HBM holds 2 bytes/weight of codes (never a float W_r); the dequant
    happens in-register per (bk, bn) tile and the DoRA low-rank +
    magnitude ride the same K loop (kernels/dora_linear.py)."""

    name = "codes"

    def linear(self, x, xw, adapter, acfg, *, accum="f32"):
        if isinstance(xw, ShardedPrepared):
            # tensor-parallel leaf inside a shard_map decode step: run
            # the ordinary prepared kernel on this device's column
            # slice, then the zero-scatter psum epilogue rebuilds the
            # full activation bitwise (columns are disjoint).
            y = rimc_linear_prepared(
                x, xw.local, interpret=X.default_interpret(), accum=accum
            )
            return tp_column_allgather(y, xw.n_total, xw.axis)
        if isinstance(xw, PreparedCrossbar):
            # serve-time prepared leaf: operands already padded/fused
            # (+ s8-recoded for int8); per-call work is the x pad only
            return rimc_linear_prepared(
                x, xw, interpret=X.default_interpret(), accum=accum
            )
        gamma = _gamma_for(xw, adapter, acfg)
        if not adapter or acfg.kind == "none":
            adapter = _zero_adapter(xw.g_pos.shape[-2], xw.g_pos.shape[-1])
        if gamma is None:
            gamma = jnp.ones((1, xw.g_pos.shape[-1]), jnp.float32)
        return X.rimc_linear(
            x, xw, adapter, gamma, interpret=X.default_interpret(),
            accum=accum,
        )


_ADC_DEFAULTS = RramConfig()


def resolve_adc_limits(rram_cfg, code_max, adc_bits):
    """Single source of truth for the ADC-faithful backend's limits: the
    deployment's ``RramConfig``. An explicit ``code_max``/``adc_bits``
    that CONFLICTS with a provided config raises (it used to be silently
    accepted, letting a session serve with an ADC the array was never
    programmed for); with no config, explicit values apply and the
    defaults mirror ``RramConfig()``."""
    if rram_cfg is not None:
        for name, explicit, want in (
            ("code_max", code_max, rram_cfg.code_max),
            ("adc_bits", adc_bits, rram_cfg.adc_bits),
        ):
            if explicit is not None and int(explicit) != int(want):
                raise ValueError(
                    f"codes_adc {name}={explicit} conflicts with the "
                    f"deployment's RramConfig.{name}={want}; the RramConfig "
                    f"is the single source of truth — drop the override or "
                    f"change the config"
                )
        return int(rram_cfg.code_max), int(rram_cfg.adc_bits)
    return (
        int(_ADC_DEFAULTS.code_max if code_max is None else code_max),
        int(_ADC_DEFAULTS.adc_bits if adc_bits is None else adc_bits),
    )


@register_backend
class CodesAdcBackend(Backend):
    """ADC-faithful analog chain: saturating ADC per 256-row crossbar
    activation (kernels/crossbar_mvm.py), then the DoRA compensation is
    applied digitally — exactly the paper's periphery split.

    ``code_max``/``adc_bits`` come from the deployment's ``RramConfig``
    (pass ``rram_cfg=`` or let ``serving.backend_scope`` plumb it);
    conflicting explicit overrides raise via ``resolve_adc_limits``."""

    name = "codes_adc"

    def linear(
        self, x, xw, adapter, acfg, *,
        rram_cfg=None, code_max=None, adc_bits=None,
    ):
        code_max, adc_bits = resolve_adc_limits(rram_cfg, code_max, adc_bits)
        if isinstance(xw, PreparedCrossbar):
            raise TypeError(
                "codes_adc reads raw per-leaf codes; prepared (fused/"
                "padded) trees are codes-backend serving artifacts"
            )
        y = X.rimc_mvm_adc(
            x, xw, code_max=code_max, adc_bits=adc_bits,
            interpret=X.default_interpret(),
        )
        y = y.astype(jnp.float32)
        if adapter and "lora_a" in adapter:
            a = adapter["lora_a"].astype(jnp.float32)
            b = adapter["lora_b"].astype(jnp.float32)
            y = y + (x.astype(jnp.float32) @ a) @ b
        gamma = _gamma_for(xw, adapter, acfg)
        if gamma is not None:
            y = y * gamma
        return y.astype(x.dtype)
