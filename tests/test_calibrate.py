"""Calibration engine: programming determinism, Algorithm 1 loop, and
end-to-end accuracy recovery on a tiny model (the paper's core claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import calibrate, dora, rram
from repro.core.calibrate import calibrate_layer, program_model
from repro.core.rram import RramConfig
from repro.optim.adam import AdamW


def test_program_model_deterministic_and_leaf_selective():
    key = jax.random.PRNGKey(0)
    tree = {
        "layer": {"w": jax.random.normal(key, (16, 8))},
        "norm": {"scale": jnp.ones((8,))},
        "ffn": {"gate_w": jax.random.normal(key, (2, 16, 8))},
    }
    cfg = RramConfig(relative_drift=0.2)
    a = program_model(tree, cfg, jax.random.PRNGKey(1))
    b = program_model(tree, cfg, jax.random.PRNGKey(1))
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # norms untouched; weights drifted
    np.testing.assert_array_equal(np.asarray(a["norm"]["scale"]), 1.0)
    assert float(jnp.abs(a["layer"]["w"] - tree["layer"]["w"]).max()) > 0
    assert float(jnp.abs(a["ffn"]["gate_w"] - tree["ffn"]["gate_w"]).max()) > 0
    # different programming key -> different deployment state
    c = program_model(tree, cfg, jax.random.PRNGKey(2))
    assert float(jnp.abs(a["layer"]["w"] - c["layer"]["w"]).max()) > 0


def test_rram_bytes_counts_differential_pairs():
    tree = {"layer": {"w": jnp.zeros((16, 8))}, "norm": {"scale": jnp.ones(8)}}
    assert calibrate.rram_bytes(tree) == 2 * 16 * 8


def test_calibrate_layer_restores_single_linear():
    """Algorithm 1 on one layer: drifted W + DoRA trained on 10 samples
    recovers the teacher's outputs."""
    key = jax.random.PRNGKey(0)
    d, k, n = 32, 16, 10
    w_t = jax.random.normal(key, (d, k)) * 0.3
    rcfg = RramConfig(relative_drift=0.20)
    w_r = rram.drifted_weights(w_t, rcfg, jax.random.PRNGKey(1), jnp.float32)
    acfg = dora.AdapterConfig(rank=4, kind="dora")
    adapter = dora.init_adapter(jax.random.PRNGKey(2), d, k, acfg, w_base=w_r)
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    y_t = x @ w_t

    def layer_fn(base, ad, xx):
        return dora.adapted_forward(xx, base, ad, acfg)

    before = float(jnp.mean((layer_fn(w_r, adapter, x) - y_t) ** 2))
    adapter, result = calibrate_layer(
        layer_fn, w_r, adapter, x, y_t,
        opt=AdamW(lr=1e-2), max_epochs=500,
    )
    after = float(jnp.mean((layer_fn(w_r, adapter, x) - y_t) ** 2))
    # rank-4 DoRA cannot exactly represent a rank-16 drift restricted to a
    # 10-sample input span; a >5x MSE reduction is the paper-level effect
    assert after < before * 0.2
    assert result.epochs_run == 500  # no threshold -> runs all epochs


def test_calibrate_layer_threshold_early_stop():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 4)) * 0.1
    acfg = dora.AdapterConfig(rank=2)
    ad = dora.init_adapter(jax.random.PRNGKey(1), 8, 4, acfg, w_base=w)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    y = x @ w  # identical teacher: loss ~0 at init (DoRA init preserving)
    _, res = calibrate_layer(
        lambda b, a, xx: dora.adapted_forward(xx, b, a, acfg),
        w, ad, x, y, max_epochs=50, loss_threshold=1e-6,
    )
    assert res.epochs_run <= 2


def test_dora_beats_lora_on_drifted_linear():
    """Fig. 6's mechanism at unit scale: with drift, DoRA's magnitude
    vector recovers column scales that LoRA at the same rank struggles
    with. We check DoRA reaches a lower MSE than LoRA for equal budget."""
    key = jax.random.PRNGKey(0)
    d, k, n = 48, 32, 10
    w_t = jax.random.normal(key, (d, k)) * 0.3
    rcfg = RramConfig(relative_drift=0.25)
    w_r = rram.drifted_weights(w_t, rcfg, jax.random.PRNGKey(1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    y_t = x @ w_t
    out = {}
    for kind in ("dora", "lora"):
        acfg = dora.AdapterConfig(rank=1, kind=kind)
        ad = dora.init_adapter(jax.random.PRNGKey(2), d, k, acfg, w_base=w_r)
        ad, _ = calibrate_layer(
            lambda b, a, xx: dora.adapted_forward(xx, b, a, acfg),
            w_r, ad, x, y_t, opt=AdamW(lr=5e-3), max_epochs=200,
        )
        out[kind] = float(
            jnp.mean((dora.adapted_forward(x, w_r, ad, acfg) - y_t) ** 2)
        )
    assert out["dora"] < out["lora"]


def test_merge_adapters_for_serve_preserves_outputs():
    """Merged-magnitude serving (Algorithm 2 line 12, §Perf H-6) must be
    numerically identical to the live-norm forward."""
    import jax
    from repro.configs import get_arch
    from repro.models import transformer as T

    cfg = get_arch("qwen3-1.7b").smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    student = program_model(params["base"], cfg.rram, jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)}
    live = T.forward({"base": student, "adapters": params["adapters"]}, batch, cfg)
    merged = calibrate.merge_adapters_for_serve(student, params["adapters"])
    served = T.forward({"base": student, "adapters": merged}, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(live, np.float32), np.asarray(served, np.float32),
        rtol=0.05, atol=0.05,
    )


def test_merge_adapters_handles_moe_stacks():
    import jax
    from repro.configs import get_arch
    from repro.models import transformer as T

    cfg = get_arch("deepseek-v2-lite-16b").smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    merged = calibrate.merge_adapters_for_serve(params["base"], params["adapters"])
    # every dora_m leaf replaced by dora_m_merged
    names = [
        str(getattr(p[-1], "key", ""))
        for p, _ in jax.tree_util.tree_flatten_with_path(merged)[0]
    ]
    assert "dora_m" not in names
    assert any(n == "dora_m_merged" for n in names)


@pytest.mark.parametrize(
    "arch_id",
    # decoder-only / enc-dec untied (lm_head term) / vision prefix
    ["qwen3-1.7b", "seamless_m4t_large_v2", "paligemma_3b"],
)
def test_cached_calib_step_matches_fused_loss(arch_id):
    """§Perf H-9: cached-teacher step loss == fused interleaved loss —
    now for every stack family (the cache stores encoder features, the
    normed enc_out memory, the vision-prefixed decoder chain, and the
    untied lm_head logits)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.deploy.deployment import calibration_batch
    from repro.models import transformer as T
    from repro.optim.adam import adamw_init

    cfg = get_arch(arch_id).smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    student = program_model(params["base"], cfg.rram, jax.random.PRNGKey(1))
    batch = calibration_batch(cfg, 2, 16)
    fused, _ = T.feature_calibration_loss(
        params["base"], student, params["adapters"], batch, cfg
    )
    feats = calibrate.teacher_features(params["base"], batch, cfg)
    state = calibrate.CalibState(
        params["base"], student, params["adapters"],
        adamw_init(params["adapters"]), jnp.zeros((), jnp.int32),
    )
    step = calibrate.make_cached_calib_step(cfg)
    _, metrics = jax.jit(step)(state, feats, batch)
    # bf16 block outputs re-round under different XLA programs; the
    # per-term structure is identical (enc pairs + dec pairs + lm_head,
    # averaged over n_terms)
    assert abs(float(fused) - float(metrics["loss"])) < 5e-3

    # caching is bitwise-reproducible: a second trace of the same batch
    # is leaf-for-leaf identical, so cache reuse can never drift a run
    feats2 = calibrate.teacher_features(params["base"], batch, cfg)
    for a, b in zip(
        jax.tree_util.tree_leaves(feats), jax.tree_util.tree_leaves(feats2)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
