"""Fleet subsystem: batched multi-chip programming/drift/calibration is
bitwise-identical to N independent ``Deployment`` runs, heterogeneous
drift clocks commute across chips, the recalibration scheduler fires iff
the drift proxy crosses its threshold, snapshot/restore replays exactly,
and the batched path never retraces per chip (ISSUE 5 acceptance)."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import rram
from repro.deploy import Deployment, serving
from repro.fleet import (
    Fleet,
    RecalibrationScheduler,
    chip_keys,
    fleet_compile_count,
)


def _cfg():
    return get_arch("qwen3_1_7b").smoke


def _leaves(tree):
    return jax.tree_util.tree_leaves(
        tree, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )


def _assert_trees_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb) and len(la) > 0
    for x, y in zip(la, lb):
        if isinstance(x, rram.CrossbarWeight):
            assert isinstance(y, rram.CrossbarWeight)
            np.testing.assert_array_equal(np.asarray(x.g_pos), np.asarray(y.g_pos))
            np.testing.assert_array_equal(np.asarray(x.g_neg), np.asarray(y.g_neg))
            np.testing.assert_array_equal(np.asarray(x.scale), np.asarray(y.scale))
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _solo_deployments(fleet, backend):
    """The N independent single-chip deployments fleet chip i must match."""
    return [
        Deployment.program(
            fleet.cfg, (fleet.teacher_key, fleet.chip_key(i)), backend=backend
        )
        for i in range(fleet.n_chips)
    ]


# -- batched programming and calibration vs N Deployments --------------------


@pytest.mark.parametrize("backend", ["dequant", "codes"])
def test_fleet_calibration_bitwise_matches_independent_deployments(backend):
    """The headline contract: program + drift + ONE vmapped calibration
    over the fleet == N independent Deployment lifecycles with the same
    per-chip keys, bitwise (codes, per-step losses, adapters, optimizer
    state)."""
    cfg = _cfg()
    n = 3
    fleet = Fleet.program(cfg, 0, n_chips=n, backend=backend)
    deps = _solo_deployments(fleet, backend)

    for i in range(n):
        _assert_trees_equal(deps[i].codes, fleet.chip(i).codes)
        _assert_trees_equal(deps[i].base, fleet.chip(i).base)

    hours = [24.0, 168.0, 6.0]  # heterogeneous aging before calibration
    fleet.advance(hours)
    for dep, h in zip(deps, hours):
        dep.advance(h)

    report = fleet.calibrate(4, steps=3, seq_len=16)
    assert report.losses.shape == (3, n)
    for i, dep in enumerate(deps):
        solo = dep.calibrate(4, steps=3, seq_len=16)
        np.testing.assert_array_equal(
            np.asarray(solo.losses, np.float32), report.losses[:, i]
        )
        chip = fleet.chip(i)
        _assert_trees_equal(dep.adapters, chip.adapters)
        _assert_trees_equal(dep.opt_state, chip.opt_state)
        assert chip.step == dep.step
        assert chip.drift_hours == dep.drift_hours

    # and the served artifact matches chip-by-chip
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0, cfg.vocab)
    for i in (0, n - 1):
        l_solo, _ = deps[i].serve().prefill(prompt, 7)
        l_fleet, _ = fleet.serve(i).prefill(prompt, 7)
        np.testing.assert_array_equal(np.asarray(l_solo), np.asarray(l_fleet))


def test_fleet_shares_teacher_and_peripherals():
    """Digital peripherals are SHARED buffers (one copy fleet-wide);
    only RRAM leaves carry the chip axis."""
    cfg = _cfg()
    fleet = Fleet.program(cfg, 0, n_chips=4)
    emb = fleet.base["embed"]["embedding"]
    assert emb is fleet.teacher_base["embed"]["embedding"]  # not a copy
    # RRAM leaves are stacked with the chip axis (leading the scan-group
    # axis for body layers)
    w = fleet.codes["body"][0]["mixer"]["q"]["w"]
    assert isinstance(w, rram.CrossbarWeight)
    assert w.g_pos.shape[0] == 4


def test_chip_keys_match_fold_in():
    key = jax.random.PRNGKey(3)
    ks = chip_keys(key, 5)
    for i in range(5):
        np.testing.assert_array_equal(
            np.asarray(ks[i]), np.asarray(jax.random.fold_in(key, i))
        )


# -- heterogeneous drift clocks ----------------------------------------------


def test_heterogeneous_clocks_deterministic_and_order_independent():
    """Per-chip histories are what matter, not the order chips were
    advanced in: any interleaving of calls that gives every chip the
    same ordered event list lands on identical state."""
    cfg = _cfg()
    a = Fleet.program(cfg, 0, n_chips=3, backend="codes")
    b = Fleet.program(cfg, 0, n_chips=3, backend="codes")

    # a: one batched heterogeneous call, then a second tick for chip 1
    a.advance([24.0, 48.0, 6.0])
    a.advance(12.0, chips=[1])
    # b: same per-chip histories via a completely different interleaving
    b.advance(6.0, chips=[2])
    b.advance(48.0, chips=[1])
    b.advance(12.0, chips=[1])
    b.advance(24.0, chips=[0])

    assert a.drift_hours == b.drift_hours == [[24.0], [48.0, 12.0], [6.0]]
    _assert_trees_equal(a.codes, b.codes)

    # determinism: replaying the same calls reproduces the same state
    c = Fleet.program(cfg, 0, n_chips=3, backend="codes")
    c.advance([24.0, 48.0, 6.0])
    c.advance(12.0, chips=[1])
    _assert_trees_equal(a.codes, c.codes)


def test_fleet_advance_validation():
    cfg = _cfg()
    fleet = Fleet.program(cfg, 0, n_chips=2)
    ref = fleet.chip(0).codes
    with pytest.raises(ValueError):
        fleet.advance(-1.0)
    with pytest.raises(ValueError):
        fleet.advance([1.0], chips=[0, 1])  # length mismatch
    with pytest.raises(ValueError):
        fleet.advance(1.0, chips=[0, 0])  # duplicate
    with pytest.raises(ValueError):
        fleet.advance(1.0, chips=[5])  # out of range
    # zero hours: true no-op, no event recorded
    fleet.advance(0.0)
    fleet.advance([0.0, 0.0])
    assert fleet.drift_hours == [[], []]
    _assert_trees_equal(ref, fleet.chip(0).codes)


# -- recalibration scheduler -------------------------------------------------


def test_scheduler_fires_iff_proxy_crosses_threshold():
    """The scheduler recalibrates exactly the chips whose drift proxy
    exceeds the threshold — aged chips fire, fresh chips don't, and a
    just-recalibrated chip's proxy resets below threshold."""
    cfg = _cfg()
    fleet = Fleet.program(cfg, 0, n_chips=4)
    sched = RecalibrationScheduler(
        fleet, threshold=0.01,
        calib_args={"batch_or_samples": 4, "steps": 2, "seq_len": 16},
    )
    # chips 0/1 age hard, chips 2/3 barely
    rec = sched.tick([300.0, 300.0, 0.5, 0.5])
    over = set(int(c) for c in np.flatnonzero(rec.proxy > 0.01))
    assert set(rec.recalibrated) == over == {0, 1}
    assert rec.report is not None and rec.report.chips == [0, 1]

    # a tiny follow-up tick: nobody (incl. the recalibrated) crosses
    rec2 = sched.tick(0.25)
    assert rec2.recalibrated == []
    assert np.all(rec2.proxy <= 0.01)
    assert rec2.report is None

    # the economics: 2 triggered vs 8 naive fixed-interval
    report = sched.report()
    assert report.recalibrations == 2
    assert report.naive_recalibrations == 8
    assert report.recalibrations_avoided == 6
    assert report.per_chip_recalibrations == [1, 1, 0, 0]
    assert report.sram_lifespan_calibrations > report.rram_lifespan_calibrations
    assert "avoided" in report.summary()


def test_scheduler_rejects_nonpositive_threshold():
    fleet = Fleet.program(_cfg(), 0, n_chips=1)
    with pytest.raises(ValueError):
        RecalibrationScheduler(fleet, threshold=0.0)


def test_scheduler_rejects_hard_threshold_below_drift_threshold():
    fleet = Fleet.program(_cfg(), 0, n_chips=1)
    with pytest.raises(ValueError, match="hard_threshold"):
        RecalibrationScheduler(fleet, threshold=0.02, hard_threshold=0.01)


def test_scheduler_discriminates_hard_faults_from_drift():
    """Non-ideality suite acceptance: a stuck-at chip fires the HARD
    path (longer calibration + permanent flag), a heavily drifted but
    healthy chip fires the DRIFT path, a fresh chip fires neither — and
    the FleetReport accounts both paths separately."""
    cfg = _cfg()
    from repro.faults import stuck_at

    fleet = Fleet.program(cfg, 0, n_chips=3)
    fleet.inject(stuck_at(7, rate=0.05), chips=[0])
    sched = RecalibrationScheduler(
        fleet, threshold=0.02, hard_threshold=0.3,
        calib_args={"batch_or_samples": 4, "steps": 2, "seq_len": 16},
    )
    # chip 0: stuck cells + mild aging; chip 1: drift only; chip 2: fresh
    rec = sched.tick([50.0, 300.0, 0.0])
    assert rec.hard_faulted == [0]
    assert rec.recalibrated == [1]  # hard chip excluded from drift path
    assert rec.hard_proxy[0] > 0.3 > rec.hard_proxy[1]
    assert rec.hard_proxy[2] == 0.0
    assert rec.report is not None and rec.report.chips == [1]
    assert rec.hard_report is not None and rec.hard_report.chips == [0]
    # hard path defaults to 2x the drift-path calibration effort
    assert rec.hard_report.epochs_run == 2 * rec.report.epochs_run

    # after compensation the proxies reset: nothing refires immediately
    rec2 = sched.tick(0.25)
    assert rec2.hard_faulted == [] and rec2.recalibrated == []

    report = sched.report()
    assert report.recalibrations == 2
    assert report.drift_recalibrations == 1
    assert report.hard_recalibrations == 1
    assert report.per_chip_hard_recalibrations == [1, 0, 0]
    assert report.hard_faulted_chips == [0]  # flagged for life
    assert report.per_chip_recalibrations == [0, 1, 0]
    assert "hard-faulted" in report.summary()
    json.loads(report.to_json())


def test_drift_proxy_zero_after_program_and_grows_with_age():
    cfg = _cfg()
    fleet = Fleet.program(cfg, 0, n_chips=2)
    np.testing.assert_array_equal(fleet.drift_proxy(), np.zeros(2))
    fleet.advance([100.0, 0.0])
    p = fleet.drift_proxy()
    assert p[0] > 0 and p[1] == 0


# -- snapshot / restore ------------------------------------------------------


def test_fleet_snapshot_restore_replays_to_exact_equality(tmp_path):
    cfg = _cfg()
    fleet = Fleet.program(cfg, 0, n_chips=3, backend="codes")
    fleet.advance([24.0, 168.0, 6.0])
    fleet.calibrate(4, steps=2, seq_len=16, chips=[0, 2])
    fleet.advance(12.0, chips=[1])
    step = fleet.snapshot(str(tmp_path))

    restored = Fleet.restore(cfg, str(tmp_path))
    assert restored.backend == "codes"
    assert restored.n_chips == 3
    assert restored.steps == fleet.steps == [2, 0, 2]
    assert restored.drift_hours == fleet.drift_hours
    _assert_trees_equal(fleet.codes, restored.codes)
    _assert_trees_equal(fleet.adapters, restored.adapters)
    _assert_trees_equal(fleet.opt_state, restored.opt_state)
    for a, b in zip(fleet._proxy_ref, restored._proxy_ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(fleet.drift_proxy(), restored.drift_proxy())
    # snapshot key = total calibration steps + total drift events, so a
    # drift-only maintenance tick still produces a NEW snapshot instead
    # of overwriting the previous one
    assert step == sum(fleet.steps) + sum(len(h) for h in fleet.drift_hours)
    fleet.advance(1.0, chips=[0])
    step2 = fleet.snapshot(str(tmp_path))
    assert step2 == step + 1

    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 4), 0, cfg.vocab)
    l1, _ = fleet.serve(1).prefill(prompt, 6)
    l2, _ = restored.serve(1).prefill(prompt, 6)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


# -- zero per-chip retraces (extends the PR 3 guarantee) ---------------------


def test_fleet_calibrate_and_serve_do_not_retrace_per_chip():
    """Compile counts must scale with SHAPES, not with chips: one fleet
    calibration compiles one vmapped step regardless of fleet size, a
    repeat same-size calibration compiles nothing new, and serving chip
    after chip reuses the per-(cfg, backend) serving steps."""
    cfg = _cfg()
    n = 3
    fleet = Fleet.program(cfg, 0, n_chips=n, backend="codes")
    fleet.advance(24.0)

    # lr=2e-3 forces a registry entry other tests haven't warmed, so the
    # compile deltas below are exactly this test's
    base = fleet_compile_count(cfg)
    fleet.calibrate(4, steps=3, seq_len=16, lr=2e-3)
    after_first = fleet_compile_count(cfg)
    assert after_first == base + 1  # ONE compiled step for the whole fleet

    fleet.calibrate(4, steps=3, seq_len=16, lr=2e-3)  # same shapes: no
    assert fleet_compile_count(cfg) == after_first    # new compile

    # a different chip-subset size is a new SHAPE (one compile), still
    # not per-chip
    fleet.calibrate(4, steps=2, seq_len=16, lr=2e-3, chips=[0, 1])
    assert fleet_compile_count(cfg) == after_first + 1

    # serving: chip 0 warms the (cfg, backend) registry; every further
    # chip reuses it
    prompt = jnp.zeros((1, 4), jnp.int32)
    s0 = fleet.serve(0)
    s0.generate(prompt, gen_len=3)
    with s0.scope():
        warm = serving.compile_count(cfg)
    assert warm > 0
    for i in range(1, n):
        fleet.serve(i).generate(prompt, gen_len=3)
    with s0.scope():
        assert serving.compile_count(cfg) == warm
