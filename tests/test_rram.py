"""RRAM compact model: programming, drift, MVM, Table I arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rram


def test_program_roundtrip_quantization_error():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 32)) * 0.1
    xw = rram.program(w, rram.RramConfig())
    w_hat = rram.dequantize(xw)
    # max error bounded by one code step per column
    step = np.asarray(xw.scale)[0]
    err = np.max(np.abs(np.asarray(w_hat - w)), axis=0)
    assert np.all(err <= step * 0.5 + 1e-7)


def test_differential_pair_exclusivity():
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (32, 16))
    xw = rram.program(w, rram.RramConfig())
    gp, gn = np.asarray(xw.g_pos, np.int32), np.asarray(xw.g_neg, np.int32)
    # one side of the pair is always zero (standard differential encoding)
    assert np.all((gp == 0) | (gn == 0))


def test_drift_statistics():
    """Drift is RELATIVE to each cell's target conductance (paper §II-A:
    |G_drift| < 20% of G_t): per-cell sigma ~ rel * G_t."""
    cfg = rram.RramConfig(relative_drift=0.10)
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (256, 256))
    xw = rram.program(w, cfg)
    xd = rram.apply_drift(xw, cfg, jax.random.PRNGKey(3))
    gp0 = np.asarray(xw.g_pos, np.float64)
    gp1 = np.asarray(xd.g_pos, np.float64)
    interior = (gp0 > 80) & (gp0 < 175)
    assert interior.sum() > 1000
    rel = ((gp1 - gp0) / np.maximum(gp0, 1))[interior]
    assert abs(rel.std() - 0.10) / 0.10 < 0.25
    # zero-conductance (unformed) cells never drift
    zeros = gp0 == 0
    assert np.all(gp1[zeros] == 0)


def test_drift_zero_is_identity():
    cfg = rram.RramConfig(relative_drift=0.0)
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    xw = rram.program(w, cfg)
    xd = rram.apply_drift(xw, cfg, jax.random.PRNGKey(1))
    assert np.array_equal(np.asarray(xw.g_pos), np.asarray(xd.g_pos))


def test_drifted_weights_fused_path_matches_explicit():
    cfg = rram.RramConfig(relative_drift=0.15)
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (64, 48))
    k = jax.random.PRNGKey(5)
    explicit = rram.dequantize(
        rram.apply_drift(rram.program(w, cfg), cfg, k), jnp.float32
    )
    fused = rram.drifted_weights(w, cfg, k, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(explicit), np.asarray(fused))


def test_mvm_reference_no_adc_is_matmul():
    cfg = rram.RramConfig(simulate_adc=False)
    key = jax.random.PRNGKey(6)
    w = jax.random.normal(key, (128, 64)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 128))
    xw = rram.program(w, cfg)
    np.testing.assert_allclose(
        np.asarray(rram.mvm_reference(x, xw, cfg)),
        np.asarray(x @ rram.dequantize(xw)),
        rtol=1e-5, atol=1e-5,
    )


def test_mvm_adc_close_to_exact():
    cfg = rram.RramConfig(simulate_adc=True, adc_bits=8, array_rows=128)
    key = jax.random.PRNGKey(8)
    w = jax.random.normal(key, (256, 64)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 256))
    xw = rram.program(w, cfg)
    exact = np.asarray(x @ rram.dequantize(xw))
    adc = np.asarray(rram.mvm_reference(x, xw, cfg))
    rel = np.abs(adc - exact) / (np.abs(exact).max() + 1e-9)
    assert rel.max() < 0.05  # 8-bit ADC keeps MVM within a few percent


# Table I — must match the paper's arithmetic exactly
def test_table1_backprop_lifespan():
    assert rram.lifespan_calibrations(
        samples=120, epochs=20, batch=1, on_rram=True
    ) == pytest.approx(41666.67, rel=1e-3)


def test_table1_dora_lifespan():
    assert rram.lifespan_calibrations(
        samples=10, epochs=20, batch=1, on_rram=False
    ) == pytest.approx(5e13, rel=1e-6)


def test_table1_speedup_1250x():
    assert rram.calibration_speedup() == pytest.approx(1250.0)
