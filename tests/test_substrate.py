"""Substrate: checkpoint manager, fault runtime, data pipeline, optimizer,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, global_batch_at_step, shard_batch_at_step
from repro.optim.adam import AdamW, adamw_init, adamw_update
from repro.optim import compress
from repro.runtime.fault import (
    ElasticPlan, PreemptionGuard, StragglerDetector, StepTimer,
)


# -- checkpoint ---------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
        "lst": [jnp.ones((2,)), jnp.zeros((3,))],
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(3, {"adapters": t})
    out = mgr.restore(3, {"adapters": t})
    for a, b in zip(
        jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(out["adapters"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"t": _tree(s)})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"t": _tree()}, blocking=False)
    mgr.save(2, {"t": _tree(1)}, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1, 2]


def test_checkpoint_no_partial_dirs_on_overwrite(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"t": _tree()})
    mgr.save(1, {"t": _tree(1)})  # overwrite same step
    assert mgr.all_steps() == [1]
    assert not any(d.startswith("tmp.") for d in os.listdir(tmp_path))


def test_checkpoint_restore_casts_dtype(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jnp.ones((3,), jnp.float32)}
    mgr.save(1, {"p": t})
    like = {"p": {"w": jnp.ones((3,), jnp.bfloat16)}}
    out = mgr.restore(1, like)
    assert out["p"]["w"].dtype == jnp.bfloat16


# -- fault runtime -------------------------------------------------------------


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(window=32, min_samples=8)
    for i in range(20):
        det.record(i, 0.1 + 0.001 * (i % 3))
    r = det.record(20, 0.5)  # 5x slower step
    assert r is not None and r.is_straggler
    assert det.reports


def test_straggler_detector_quiet_on_uniform():
    det = StragglerDetector(min_samples=8)
    for i in range(30):
        r = det.record(i, 0.1)
    assert not det.reports


def test_preemption_guard_flag():
    with PreemptionGuard(signals=()) as g:
        assert not g.should_stop
        g.request_stop()
        assert g.should_stop


def test_elastic_plan():
    p = ElasticPlan.plan(2, latest_step=40)
    assert p.new_mesh_shape == (14, 16)
    assert p.restore_step == 40
    with pytest.raises(RuntimeError):
        ElasticPlan.plan(16, latest_step=None)


def test_step_timer():
    with StepTimer() as t:
        sum(range(1000))
    assert t.elapsed >= 0


# -- data pipeline --------------------------------------------------------------


def test_data_deterministic_across_calls():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=4)
    a = global_batch_at_step(cfg, 5)
    b = global_batch_at_step(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_data_shard_slices_match_global():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    full = global_batch_at_step(cfg, 3)
    for shard in range(4):
        part = shard_batch_at_step(cfg, 3, shard, 4)
        np.testing.assert_array_equal(
            part["tokens"], full["tokens"][shard * 2 : (shard + 1) * 2]
        )


def test_data_calibration_set_cycles():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=5, n_calibration_samples=5)
    a = global_batch_at_step(cfg, 0)
    b = global_batch_at_step(cfg, 1)  # same 5 samples again
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


# -- optimizer -------------------------------------------------------------------


def test_adamw_converges_quadratic():
    params = {"x": jnp.array([3.0, -2.0])}
    opt = AdamW(lr=0.1, grad_clip=None)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, opt)
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip():
    params = {"x": jnp.array([0.0])}
    opt = AdamW(lr=1.0, grad_clip=1.0)
    state = adamw_init(params)
    g = {"x": jnp.array([1e6])}
    p2, _ = adamw_update(g, state, params, opt)
    assert abs(float(p2["x"][0])) < 10.0


def test_compress_error_feedback_reduces_bias():
    """With error feedback the accumulated quantization error stays bounded
    and the mean dequantized gradient converges to the true gradient."""
    g = {"w": jnp.linspace(-1, 1, 64)}
    residual = compress.init_residual(g)
    total = jnp.zeros((64,))
    n = 50
    for _ in range(n):
        codes, scales, residual = compress.compress(g, residual)
        total = total + codes["w"].astype(jnp.float32) * scales["w"]
    mean = np.asarray(total / n)
    np.testing.assert_allclose(mean, np.asarray(g["w"]), atol=2e-3)


def test_compress_int8_range():
    g = {"w": jnp.array([1e-3, -5.0, 7.0])}
    codes, scales, _ = compress.compress(g, compress.init_residual(g))
    assert codes["w"].dtype == jnp.int8
    assert int(jnp.max(jnp.abs(codes["w"]))) <= 127
