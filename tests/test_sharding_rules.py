"""Sharding-rules coverage over the 10-config model zoo.

``resolve_spec`` takes a plain axis-name -> size mapping, so the whole
zoo is checked abstractly on one device: every large base leaf must
match a rule (silent replication of a big weight is a rules-table gap),
the serve-TP wrap predicate must agree with the rules table, and the
non-divisible drop-to-None behaviour is pinned exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.models import transformer as T
from repro.sharding import rules as R

AXES_16x16 = {"data": 16, "model": 16}


def _abstract_base(cfg):
    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    return params["base"]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_full_config_has_no_unmatched_large_leaves(arch):
    base = _abstract_base(get_arch(arch).full)
    bad = R.unmatched_large_leaves(base)
    assert bad == [], (
        f"{arch}: large base leaves with no sharding rule (would silently "
        f"replicate): {bad}"
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_large_matrices_have_a_sharding_axis(arch):
    """Every big matrix's matched rule must name at least one mesh axis.
    Resolved against size-1 axes so the divisibility guard never fires:
    this checks the rules TABLE (a big matrix mapped to replicated by
    design is a gap), while drop-to-None on awkward dims — e.g. the
    unpadded 256206 seamless vocab vs 16-way TP — stays legal and is
    pinned separately below."""
    base = _abstract_base(get_arch(arch).full)
    axes_1 = {"data": 1, "model": 1}
    replicated_big = []
    # rules that replicate ON PURPOSE (tiny per-layer, big only because
    # they stack over layers): the MLA latent down-projection
    intentional = ("mixer/kv_down/w", "norm")

    def leaf(path, x):
        p = R._path_str(path)
        if int(np.prod(x.shape)) < 1 << 22:  # 4M elements: real matrices
            return
        if any(s in p for s in intentional):
            return
        spec = R.resolve_spec(p, x.shape, axes_1)
        if all(s is None for s in spec):
            replicated_big.append((p, tuple(x.shape), spec))

    jax.tree_util.tree_map_with_path(leaf, base)
    assert replicated_big == [], replicated_big


def test_nondivisible_dim_drops_to_none():
    # 10 % 4 != 0 -> the tp axis drops, the leaf replicates instead of
    # failing to lower; the divisible sibling keeps its spec
    axes = {"data": 2, "model": 4}
    assert R.resolve_spec("mixer/q/w", (16, 10), axes) == P(None, None)
    assert R.resolve_spec("mixer/q/w", (16, 32), axes) == P(None, "model")
    # dp tuple product guards too: ("pod", "data") = 4 does not divide 6
    assert R.resolve_spec(
        "ffn/down/w", (6, 32), {"pod": 2, "data": 2, "model": 4},
        dp=("pod", "data"),
    ) == P(None, None)


def test_expert_stack_prefers_ep_falls_back_2d():
    # 64 experts divide model=16 -> expert-parallel over the model axis
    assert R.resolve_spec(
        "ffn/gate_w", (64, 2048, 1408), AXES_16x16
    ) == P("model", None, None)
    # 8 experts don't divide model=16 -> 2D (d over data, ff over model)
    assert R.resolve_spec(
        "ffn/gate_w", (8, 6144, 16384), AXES_16x16
    ) == P(None, ("data",), "model")


def test_serve_tp_wrap_predicate_matches_rules():
    # column-parallel serve leaves (fused and unfused) are wrappable
    for p in (
        "body/0/mixer/_qkv/w", "body/0/mixer/_q_kvd/w",
        "body/0/mixer/_kup_vup/w", "body/0/ffn/_gate_up/w",
        "body/0/ffn/shared/_gate_up/w", "lm_head/w", "body/0/mixer/o/w",
    ):
        assert R.serve_tp_shardable(p), p
    # explicit-replicate and unmatched paths are not
    for p in (
        "body/0/norm1/scale", "body/0/mixer/kv_down/w",
        "body/0/ffn/router/w", "adapters/whatever/lora_a",
    ):
        assert not R.serve_tp_shardable(p), p


def test_explicit_norm_rule_replicates():
    spec = R.resolve_spec("body/0/norm1/scale", (32, 4096), AXES_16x16)
    assert spec == P(None, None)
    assert R.match_rule(R.PARAM_RULES, "body/0/norm2/bias") == ()


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "deepseek_v2_lite_16b",
                                  "mixtral_8x22b"])
def test_smoke_configs_resolve_without_error(arch):
    """The serve-TP smoke configs: every leaf resolves, and anything the
    wrap policy would shard keeps a 'model' axis at tp=4."""
    base = _abstract_base(get_arch(arch).smoke)
    axes = {"data": 2, "model": 4}

    def leaf(path, x):
        p = R._path_str(path)
        spec = R.resolve_spec(p, x.shape, axes)
        assert len(spec) <= x.ndim

    jax.tree_util.tree_map_with_path(leaf, base)
