import gc

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop compiled XLA executables when a test module finishes.

    Every live executable holds several process memory mappings; across
    the whole suite the accumulated programs (decode/prefill step
    registry, calibration steps, kernels...) blow past the kernel's
    vm.max_map_count default (65530) and later compilations die with
    SIGSEGV inside XLA. Modules are compile-disjoint (different configs
    and step shapes), so clearing at module boundaries bounds the map
    count without perturbing any within-module retrace counter.
    """
    yield
    jax.clear_caches()
    gc.collect()
