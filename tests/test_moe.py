"""MoE dispatch correctness: grouped-gather path vs dense per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dora import AdapterConfig
from repro.models import layers as L
from repro.models import moe as M


def _dense_oracle(x, base, cfg: M.MoeConfig):
    """Per-token dense computation over the selected experts (no capacity)."""
    bsz, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ base["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = xf[t] @ base["gate_w"][e].astype(xf.dtype)
            u = xf[t] @ base["up_w"][e].astype(xf.dtype)
            y = (jax.nn.silu(h) * u) @ base["down_w"][e].astype(xf.dtype)
            acc = acc + gates[t, j] * y.astype(jnp.float32)
        out = out.at[t].set(acc)
    return out.reshape(bsz, s, d)


def test_moe_matches_dense_oracle_no_drops():
    cfg = M.MoeConfig(
        d_model=16, d_ff=32, n_experts=4, top_k=2, n_shared=0,
        capacity_factor=8.0,  # capacity >> needed: no drops
    )
    acfg = AdapterConfig(kind="none")
    base, _ = M.init_moe(jax.random.PRNGKey(0), cfg, acfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y = M.moe_block(x, base, None, cfg, acfg)
    y_ref = _dense_oracle(x, base, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_graceful():
    cfg = M.MoeConfig(
        d_model=16, d_ff=32, n_experts=4, top_k=2, n_shared=0,
        capacity_factor=0.25,  # force drops
    )
    acfg = AdapterConfig(kind="none")
    base, _ = M.init_moe(jax.random.PRNGKey(0), cfg, acfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y = M.moe_block(x, base, None, cfg, acfg)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())


def test_moe_shared_experts_added():
    cfg = M.MoeConfig(
        d_model=16, d_ff=32, n_experts=4, top_k=2, n_shared=1,
        capacity_factor=8.0,
    )
    acfg = AdapterConfig(kind="none")
    base, _ = M.init_moe(jax.random.PRNGKey(0), cfg, acfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
    y = M.moe_block(x, base, None, cfg, acfg)
    y_no_shared = _dense_oracle(x, base, cfg)
    mcfg = L.MlpConfig(16, 32, gated=True, activation="silu")
    shared = L.mlp(x, base["shared"], None, mcfg, acfg)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_no_shared + shared.astype(jnp.float32)),
        rtol=2e-4, atol=2e-4,
    )


def test_moe_dora_adapters_change_output_and_identity_at_init():
    cfg = M.MoeConfig(
        d_model=16, d_ff=32, n_experts=4, top_k=2, capacity_factor=8.0
    )
    acfg = AdapterConfig(rank=2, kind="dora")
    base, ad = M.init_moe(jax.random.PRNGKey(0), cfg, acfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    y0 = M.moe_block(x, base, None, cfg, acfg)
    y1 = M.moe_block(x, base, ad, cfg, acfg)
    # DoRA init is output-preserving (B=0, M=||W||)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-3, atol=2e-3)
    ad2 = jax.tree_util.tree_map(lambda v: v, ad)
    ad2["down_w"]["dora_m"] = ad2["down_w"]["dora_m"] * 1.5
    y2 = M.moe_block(x, base, ad2, cfg, acfg)
    assert float(jnp.abs(y2 - y1).max()) > 1e-4


def test_router_gates_sum_to_one():
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (32, 8)))
    gates, _ = jax.lax.top_k(probs, 2)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0, rtol=1e-6)
