"""Continuous-batching engine: ragged/staggered bitwise parity with
per-request ``generate``, the retrace fix (zero recompilations after the
first call), fused-prefill parity with the per-token loop, and the
sampling-intent fixes (ISSUE 4 acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.deploy import Deployment, ServeEngine, serving
from repro.models import transformer as T


def _reference(session, prompt, gen_len, temperature=0.0, key=None,
               enc_embeds=None, patch_embeds=None):
    """Per-request reference: the single-stream generate loop, one call
    per prompt (batch 1) — what the engine must reproduce bitwise."""
    with session.scope():
        toks, _ = serving.generate(
            session.params, jnp.asarray(prompt, jnp.int32)[None, :],
            session.cfg, gen_len=gen_len, temperature=temperature, key=key,
            enc_embeds=None if enc_embeds is None else enc_embeds[None],
            patch_embeds=None if patch_embeds is None else patch_embeds[None],
        )
    return list(np.asarray(toks)[0])


def _ragged_staggered_check(arch, backend, *, max_len, prompt_lens, gen_len,
                            temperature=0.9, enc_lens=None, vision=False,
                            **engine_kw):
    cfg = get_arch(arch).smoke
    session = Deployment.program(cfg, 0, backend=backend).serve()
    prompts = [
        np.asarray(jax.random.randint(
            jax.random.PRNGKey(50 + i), (n,), 0, cfg.vocab
        ))
        for i, n in enumerate(prompt_lens)
    ]
    encs = [None] * len(prompts)
    if enc_lens is not None:
        encs = [
            np.asarray(jax.random.normal(
                jax.random.PRNGKey(200 + i), (n, cfg.d_model), cfg.dtype
            ))
            for i, n in enumerate(enc_lens)
        ]
        engine_kw.setdefault("src_len", max(enc_lens))
    patches = [None] * len(prompts)
    if vision:
        patches = [
            np.asarray(jax.random.normal(
                jax.random.PRNGKey(300 + i), (cfg.vision_tokens, cfg.d_model),
                cfg.dtype,
            ))
            for i in range(len(prompts))
        ]
    keys = [jax.random.PRNGKey(100 + i) for i in range(len(prompts))]
    refs = [
        _reference(session, p, gen_len, temperature, k,
                   enc_embeds=e, patch_embeds=v)
        for p, k, e, v in zip(prompts, keys, encs, patches)
    ]
    # fewer slots than requests, admissions at different ticks -> the
    # engine must interleave rows at different clocks and recycle slots
    engine = ServeEngine(session, max_slots=2, max_len=max_len, **engine_kw)
    reqs = []
    for i, (p, k, e, v) in enumerate(zip(prompts, keys, encs, patches)):
        reqs.append(
            engine.submit(p, max_new=gen_len, temperature=temperature, key=k,
                          enc_embeds=e, patch_embeds=v)
        )
        engine.step()
        engine.step()
    engine.run()
    for i, (req, ref) in enumerate(zip(reqs, refs)):
        assert req.done
        assert req.tokens == ref, f"request {i}: {req.tokens} != {ref}"
    assert engine.generated_tokens == sum(len(r.tokens) for r in reqs)
    return engine, reqs


@pytest.mark.parametrize("backend", ["dequant", "codes"])
def test_ragged_staggered_parity_dense(backend):
    """Engine output is bitwise-identical to N independent generate
    calls — ragged prompts, staggered admission, both backends."""
    _ragged_staggered_check(
        "qwen3_1_7b", backend, max_len=32,
        prompt_lens=[5, 11, 3], gen_len=6,
    )


@pytest.mark.parametrize("backend", ["dequant", "codes"])
def test_ragged_parity_sliding_window_wraparound(backend):
    """mixtral smoke (window 16): prompts + generation cross the rolling
    buffer boundary, exercising the vectorized per-slot wrap-around in
    ``_cache_mask``/``_cache_write``."""
    _ragged_staggered_check(
        "mixtral_8x22b", backend, max_len=40,
        prompt_lens=[14, 20], gen_len=8,
    )


def test_ragged_parity_mla():
    """deepseek-v2 smoke: MLA latent cache (c_kv + shared rope key) on
    the codes backend."""
    _ragged_staggered_check(
        "deepseek_v2_lite_16b", "codes", max_len=32,
        prompt_lens=[9, 4], gen_len=5,
    )


def test_slot_recycling_and_eos():
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (6,), 0, cfg.vocab)
    )
    key = jax.random.PRNGKey(7)
    ref = _reference(session, prompt, 8, temperature=1.0, key=key)
    # eos = the first token value whose FIRST occurrence is at index >= 2:
    # the engine must stop there (token included) and free the slot for
    # the queued second request
    j = next(i for i in range(2, len(ref)) if ref[i] not in ref[:i])
    engine = ServeEngine(session, max_slots=1, max_len=24)
    r1 = engine.submit(
        prompt, max_new=8, temperature=1.0, key=key, eos_id=ref[j]
    )
    r2 = engine.submit(prompt + 1, max_new=3)
    assert r2.slot is None and engine.pending  # queued: no free slot
    engine.run()
    assert r1.done and r1.tokens == ref[: j + 1]
    assert r2.done and len(r2.tokens) == 3
    assert engine.num_active == 0 and not engine.pending


def test_second_generate_call_triggers_zero_new_compilations():
    """The retrace bug: every request used to re-wrap jax.jit and
    recompile. The registry compiles on the first call only."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0, cfg.vocab)
    session.generate(prompt, gen_len=4)
    with session.scope():
        warm = serving.compile_count(cfg)
    assert warm > 0
    for _ in range(3):
        session.generate(prompt, gen_len=4)
    with session.scope():
        assert serving.compile_count(cfg) == warm
    # the engine path stays warm too: same-shape resubmission compiles 0
    engine = ServeEngine(session, max_slots=2, max_len=12)
    engine.submit(prompt[0], max_new=4)
    engine.run()
    warm = engine.compile_count()
    engine.submit(prompt[0], max_new=4)
    engine.run()
    assert engine.compile_count() == warm


def test_compile_count_warm_parity_codes_vs_dequant():
    """The codes backend compiles exactly as many step programs as the
    dequant reference for the same request mix. It used to compile twice
    as many: ``backend_scope("dequant")`` was a nullcontext, so both
    backends shared one registry entry keyed on the ambient default and
    each clobbered the other's trace cache."""
    import dataclasses

    cfg = dataclasses.replace(
        get_arch("qwen3_1_7b").smoke, name="qwen3-smoke-warm-parity"
    )
    counts = {}
    for backend in ("dequant", "codes"):
        session = Deployment.program(cfg, 0, backend=backend).serve()
        for plen in (4, 7, 4):
            prompt = jax.random.randint(
                jax.random.PRNGKey(plen), (1, plen), 0, cfg.vocab
            )
            session.generate(prompt, gen_len=3)
        with session.scope():
            counts[backend] = serving.compile_count(cfg)
    assert counts["codes"] == counts["dequant"] > 0


@pytest.mark.parametrize(
    "arch_id",
    ["qwen3_1_7b", "falcon_mamba_7b", "recurrentgemma_9b",
     "deepseek_v2_lite_16b", "mixtral_8x22b"],
)
def test_fused_prefill_matches_token_loop(arch_id):
    """Fused full-sequence prefill == per-token decode_step loop: same
    last-position logits (up to the SSM associative-vs-sequential scan
    rounding) and an identical greedy continuation from either cache."""
    cfg = get_arch(arch_id).smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    p = {"base": params["base"],
         "adapters": T._empty_adapters(params["adapters"])}
    s, max_len = 9, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    cache_l = T.init_cache(cfg, 2, max_len)
    for i in range(s):
        logits_l, cache_l = T.decode_step(
            p, cache_l, toks[:, i : i + 1], jnp.int32(i), cfg
        )
    logits_f, cache_f = T.prefill(p, toks, cfg, max_len)
    np.testing.assert_allclose(
        np.asarray(logits_l[:, -1], np.float32),
        np.asarray(logits_f[:, -1], np.float32),
        rtol=0.15, atol=0.15,
    )
    tl = jnp.argmax(logits_l[:, -1], -1)[:, None].astype(jnp.int32)
    tf = jnp.argmax(logits_f[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(4):
        assert bool((tl == tf).all())
        logits_l, cache_l = T.decode_step(p, cache_l, tl, jnp.int32(s + i), cfg)
        logits_f, cache_f = T.decode_step(p, cache_f, tf, jnp.int32(s + i), cfg)
        tl = jnp.argmax(logits_l[:, -1], -1)[:, None].astype(jnp.int32)
        tf = jnp.argmax(logits_f[:, -1], -1)[:, None].astype(jnp.int32)


def test_vector_pos_matches_scalar_pos():
    """(B,) per-slot clocks with equal entries == the legacy scalar pos."""
    cfg = get_arch("qwen3_1_7b").smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    p = {"base": params["base"],
         "adapters": T._empty_adapters(params["adapters"])}
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    c1 = T.init_cache(cfg, 2, 8)
    c2 = T.init_cache(cfg, 2, 8)
    for i in range(4):
        l1, c1 = T.decode_step(p, c1, toks[:, i : i + 1], jnp.int32(i), cfg)
        l2, c2 = T.decode_step(
            p, c2, toks[:, i : i + 1], jnp.full((2,), i, jnp.int32), cfg
        )
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_temperature_without_key_samples():
    """temperature > 0 without a key must sample (deriving a key from
    the deployment key), not silently argmax."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    t1, _ = session.generate(prompt, gen_len=2, temperature=8.0)
    t2, _ = session.generate(prompt, gen_len=2, temperature=8.0)
    greedy, _ = session.generate(prompt, gen_len=2)
    # near-uniform sampling: the derived keys differ per call, and at
    # least one draw differs from the argmax path
    assert not np.array_equal(t1, t2)
    assert not (np.array_equal(t1, greedy) and np.array_equal(t2, greedy))


def test_key_with_zero_temperature_raises():
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab)
    with pytest.raises(ValueError, match="greedily"):
        session.generate(prompt, gen_len=2, key=jax.random.PRNGKey(0))
    engine = ServeEngine(session, max_slots=1, max_len=8)
    with pytest.raises(ValueError, match="greedily"):
        engine.submit(prompt[0], max_new=2, key=jax.random.PRNGKey(0))


def test_engine_submit_validation():
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    engine = ServeEngine(session, max_slots=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        engine.submit(np.zeros(6, np.int32), max_new=4)
    with pytest.raises(ValueError, match="decoder-only"):
        engine.submit(np.zeros(2, np.int32), max_new=2,
                      enc_embeds=np.zeros((4, cfg.d_model), np.float32))
    with pytest.raises(ValueError, match="vision_tokens"):
        engine.submit(np.zeros(2, np.int32), max_new=2,
                      patch_embeds=np.zeros((4, cfg.d_model), np.float32))
    enc_cfg = get_arch("seamless_m4t_large_v2").smoke
    enc_session = Deployment.program(enc_cfg, 0).serve()
    with pytest.raises(ValueError, match="src_len"):
        ServeEngine(enc_session)  # enc-dec engine needs the encoder extent
    enc_engine = ServeEngine(enc_session, max_slots=1, max_len=16, src_len=4)
    with pytest.raises(ValueError, match="enc_embeds"):
        enc_engine.submit(np.zeros(2, np.int32), max_new=2)
    with pytest.raises(ValueError, match="src_len"):
        enc_engine.submit(
            np.zeros(2, np.int32), max_new=2,
            enc_embeds=np.zeros((6, enc_cfg.d_model), np.float32),
        )
    vis_cfg = get_arch("paligemma_3b").smoke
    vis_session = Deployment.program(vis_cfg, 0).serve()
    vis_engine = ServeEngine(vis_session, max_slots=1, max_len=16)
    with pytest.raises(ValueError, match="vision tokens"):
        vis_engine.submit(
            np.zeros(2, np.int32), max_new=2,
            patch_embeds=np.zeros((3, vis_cfg.d_model), np.float32),
        )
    # the vision prefix counts against max_len: 8 + 5 + 4 > 16
    with pytest.raises(ValueError, match="max_len"):
        vis_engine.submit(
            np.zeros(5, np.int32), max_new=4,
            patch_embeds=np.zeros(
                (vis_cfg.vision_tokens, vis_cfg.d_model), np.float32
            ),
        )


@pytest.mark.parametrize("backend", ["dequant", "codes"])
def test_ragged_staggered_parity_encdec(backend):
    """seamless smoke through the engine: per-slot cross-attention cache
    lines, ragged encoder lengths masked per slot by enc_len — bitwise
    vs per-request generate."""
    _ragged_staggered_check(
        "seamless_m4t_large_v2", backend, max_len=24,
        prompt_lens=[5, 9, 3], gen_len=5, enc_lens=[3, 4, 2],
        prefill_chunk=4, min_bucket=4,
    )


@pytest.mark.parametrize("backend", ["dequant", "codes"])
def test_ragged_staggered_parity_vision(backend):
    """paligemma smoke through the engine: image-prefix admission (the
    8 patch positions prefill bidirectionally ahead of the text chunks,
    clocks offset by vision_tokens) — bitwise vs generate."""
    _ragged_staggered_check(
        "paligemma_3b", backend, max_len=32,
        prompt_lens=[6, 10], gen_len=5, vision=True,
        prefill_chunk=4, min_bucket=4,
    )


def test_chunked_prefill_matches_fused_admission():
    """Chunk width is a scheduling knob, not a numerics knob: the same
    prompt admitted through 2-token chunks and through one fused span
    generates identical tokens."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(5), (11,), 0, cfg.vocab)
    )
    outs = []
    for chunk in (2, 16):
        engine = ServeEngine(
            session, max_slots=1, max_len=24, prefill_chunk=chunk,
            min_bucket=2, prefix_cache_entries=0,
        )
        req = engine.submit(prompt, max_new=6)
        engine.run()
        outs.append(req.tokens)
    assert outs[0] == outs[1]


def test_prefix_cache_hit_is_bitwise_and_counted():
    """A request whose prompt shares a stored prefix resumes from the
    snapshot — tokens bitwise-identical to a cold admission, hits
    visible in stats(), and full hits skip prefill chunks entirely."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    shared = np.asarray(
        jax.random.randint(jax.random.PRNGKey(8), (8,), 0, cfg.vocab)
    )
    tail = np.asarray(
        jax.random.randint(jax.random.PRNGKey(9), (5,), 0, cfg.vocab)
    )
    long = np.concatenate([shared, tail])
    cold_refs = {
        n: _reference(session, p, 5) for n, p in
        [("shared", shared), ("long", long)]
    }
    engine = ServeEngine(
        session, max_slots=1, max_len=32, prefill_chunk=4, min_bucket=4
    )
    r1 = engine.submit(shared, max_new=5)
    engine.run()
    assert r1.tokens == cold_refs["shared"] and r1.prefix_hit_tokens == 0
    chunks_cold = engine.prefill_chunks
    # exact resubmission: full snapshot hit, zero prefill chunks run
    r2 = engine.submit(shared, max_new=5)
    engine.run()
    assert r2.tokens == cold_refs["shared"]
    assert r2.prefix_hit_tokens == len(shared)
    assert engine.prefix_hits == 1 and engine.prefill_chunks == chunks_cold
    # shared system prompt + new tail: partial hit at the chunk boundary
    r3 = engine.submit(long, max_new=5)
    engine.run()
    assert r3.tokens == cold_refs["long"]
    assert r3.prefix_hit_tokens == len(shared)
    assert engine.prefix_partial_hits == 1
    st = engine.stats()
    assert st["prefix_lookups"] == 3 and st["prefix_hits"] == 1


def test_prefix_cache_full_hit_nonchunked():
    """SSM stacks don't chunk (recurrence regrouping), but an exact
    prompt resubmission still reuses the fused-prefill snapshot."""
    cfg = get_arch("falcon_mamba_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(4), (7,), 0, cfg.vocab)
    )
    ref = _reference(session, prompt, 4)
    engine = ServeEngine(session, max_slots=1, max_len=16)
    r1 = engine.submit(prompt, max_new=4)
    engine.run()
    r2 = engine.submit(prompt, max_new=4)
    engine.run()
    assert r1.tokens == ref and r2.tokens == ref
    assert engine.prefix_hits == 1 and r2.prefix_hit_tokens == 7


def test_chunk_bucketing_pins_compile_ceiling():
    """Pow-2 chunk buckets bound the jit cache: once the bucket set is
    warm, NEW ragged prompt lengths compile nothing."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    engine = ServeEngine(
        session, max_slots=2, max_len=64, prefill_chunk=8, min_bucket=4,
        prefix_cache_entries=0,
    )

    def toks(n, seed):
        return np.asarray(
            jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)
        )

    # warm the full bucket set {4, 8}: a sub-bucket tail and full chunks
    for n in (3, 12):
        engine.submit(toks(n, n), max_new=2)
    engine.run()
    warm = engine.compile_count()
    assert warm > 0
    # six unseen prompt lengths -> same buckets, zero new programs
    for n in (2, 5, 7, 9, 17, 23):
        engine.submit(toks(n, 100 + n), max_new=2)
    engine.run()
    assert engine.compile_count() == warm


def test_engine_accounting_unified_retirement():
    """first_tokens/decode_tokens/completed stay consistent across every
    exit path — max_new=1, first-token EOS, and normal retirement all
    satisfy generated_tokens == first + decode == sum(emitted)."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (5,), 0, cfg.vocab)
    )
    ref = _reference(session, prompt, 6)
    engine = ServeEngine(session, max_slots=2, max_len=16)
    r_one = engine.submit(prompt, max_new=1)          # retires at admission
    r_eos = engine.submit(prompt, max_new=6, eos_id=ref[0])  # first tok EOS
    r_full = engine.submit(prompt, max_new=6)
    engine.run()
    assert r_one.done and r_one.tokens == ref[:1]
    assert r_eos.done and r_eos.tokens == ref[:1]
    assert r_full.done and r_full.tokens == ref
    assert r_one.ttft_seconds is not None and r_eos.ttft_seconds is not None
    st = engine.stats()
    emitted = sum(len(r.tokens) for r in (r_one, r_eos, r_full))
    assert st["first_tokens"] == 3
    assert st["completed"] == 3
    assert st["generated_tokens"] == st["first_tokens"] + st["decode_tokens"]
    assert st["generated_tokens"] == emitted
