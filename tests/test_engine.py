"""Continuous-batching engine: ragged/staggered bitwise parity with
per-request ``generate``, the retrace fix (zero recompilations after the
first call), fused-prefill parity with the per-token loop, and the
sampling-intent fixes (ISSUE 4 acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.deploy import Deployment, ServeEngine, serving
from repro.models import transformer as T


def _reference(session, prompt, gen_len, temperature=0.0, key=None):
    """Per-request reference: the single-stream generate loop, one call
    per prompt (batch 1) — what the engine must reproduce bitwise."""
    with session.scope():
        toks, _ = serving.generate(
            session.params, jnp.asarray(prompt, jnp.int32)[None, :],
            session.cfg, gen_len=gen_len, temperature=temperature, key=key,
        )
    return list(np.asarray(toks)[0])


def _ragged_staggered_check(arch, backend, *, max_len, prompt_lens, gen_len,
                            temperature=0.9):
    cfg = get_arch(arch).smoke
    session = Deployment.program(cfg, 0, backend=backend).serve()
    prompts = [
        np.asarray(jax.random.randint(
            jax.random.PRNGKey(50 + i), (n,), 0, cfg.vocab
        ))
        for i, n in enumerate(prompt_lens)
    ]
    keys = [jax.random.PRNGKey(100 + i) for i in range(len(prompts))]
    refs = [
        _reference(session, p, gen_len, temperature, k)
        for p, k in zip(prompts, keys)
    ]
    # fewer slots than requests, admissions at different ticks -> the
    # engine must interleave rows at different clocks and recycle slots
    engine = ServeEngine(session, max_slots=2, max_len=max_len)
    reqs = []
    for i, (p, k) in enumerate(zip(prompts, keys)):
        reqs.append(
            engine.submit(p, max_new=gen_len, temperature=temperature, key=k)
        )
        engine.step()
        engine.step()
    engine.run()
    for i, (req, ref) in enumerate(zip(reqs, refs)):
        assert req.done
        assert req.tokens == ref, f"request {i}: {req.tokens} != {ref}"


@pytest.mark.parametrize("backend", ["dequant", "codes"])
def test_ragged_staggered_parity_dense(backend):
    """Engine output is bitwise-identical to N independent generate
    calls — ragged prompts, staggered admission, both backends."""
    _ragged_staggered_check(
        "qwen3_1_7b", backend, max_len=32,
        prompt_lens=[5, 11, 3], gen_len=6,
    )


@pytest.mark.parametrize("backend", ["dequant", "codes"])
def test_ragged_parity_sliding_window_wraparound(backend):
    """mixtral smoke (window 16): prompts + generation cross the rolling
    buffer boundary, exercising the vectorized per-slot wrap-around in
    ``_cache_mask``/``_cache_write``."""
    _ragged_staggered_check(
        "mixtral_8x22b", backend, max_len=40,
        prompt_lens=[14, 20], gen_len=8,
    )


def test_ragged_parity_mla():
    """deepseek-v2 smoke: MLA latent cache (c_kv + shared rope key) on
    the codes backend."""
    _ragged_staggered_check(
        "deepseek_v2_lite_16b", "codes", max_len=32,
        prompt_lens=[9, 4], gen_len=5,
    )


def test_slot_recycling_and_eos():
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (6,), 0, cfg.vocab)
    )
    key = jax.random.PRNGKey(7)
    ref = _reference(session, prompt, 8, temperature=1.0, key=key)
    # eos = the first token value whose FIRST occurrence is at index >= 2:
    # the engine must stop there (token included) and free the slot for
    # the queued second request
    j = next(i for i in range(2, len(ref)) if ref[i] not in ref[:i])
    engine = ServeEngine(session, max_slots=1, max_len=24)
    r1 = engine.submit(
        prompt, max_new=8, temperature=1.0, key=key, eos_id=ref[j]
    )
    r2 = engine.submit(prompt + 1, max_new=3)
    assert r2.slot is None and engine.pending  # queued: no free slot
    engine.run()
    assert r1.done and r1.tokens == ref[: j + 1]
    assert r2.done and len(r2.tokens) == 3
    assert engine.num_active == 0 and not engine.pending


def test_second_generate_call_triggers_zero_new_compilations():
    """The retrace bug: every request used to re-wrap jax.jit and
    recompile. The registry compiles on the first call only."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0, cfg.vocab)
    session.generate(prompt, gen_len=4)
    with session.scope():
        warm = serving.compile_count(cfg)
    assert warm > 0
    for _ in range(3):
        session.generate(prompt, gen_len=4)
    with session.scope():
        assert serving.compile_count(cfg) == warm
    # the engine path stays warm too: same-shape resubmission compiles 0
    engine = ServeEngine(session, max_slots=2, max_len=12)
    engine.submit(prompt[0], max_new=4)
    engine.run()
    warm = engine.compile_count()
    engine.submit(prompt[0], max_new=4)
    engine.run()
    assert engine.compile_count() == warm


def test_compile_count_warm_parity_codes_vs_dequant():
    """The codes backend compiles exactly as many step programs as the
    dequant reference for the same request mix. It used to compile twice
    as many: ``backend_scope("dequant")`` was a nullcontext, so both
    backends shared one registry entry keyed on the ambient default and
    each clobbered the other's trace cache."""
    import dataclasses

    cfg = dataclasses.replace(
        get_arch("qwen3_1_7b").smoke, name="qwen3-smoke-warm-parity"
    )
    counts = {}
    for backend in ("dequant", "codes"):
        session = Deployment.program(cfg, 0, backend=backend).serve()
        for plen in (4, 7, 4):
            prompt = jax.random.randint(
                jax.random.PRNGKey(plen), (1, plen), 0, cfg.vocab
            )
            session.generate(prompt, gen_len=3)
        with session.scope():
            counts[backend] = serving.compile_count(cfg)
    assert counts["codes"] == counts["dequant"] > 0


@pytest.mark.parametrize(
    "arch_id",
    ["qwen3_1_7b", "falcon_mamba_7b", "recurrentgemma_9b",
     "deepseek_v2_lite_16b", "mixtral_8x22b"],
)
def test_fused_prefill_matches_token_loop(arch_id):
    """Fused full-sequence prefill == per-token decode_step loop: same
    last-position logits (up to the SSM associative-vs-sequential scan
    rounding) and an identical greedy continuation from either cache."""
    cfg = get_arch(arch_id).smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    p = {"base": params["base"],
         "adapters": T._empty_adapters(params["adapters"])}
    s, max_len = 9, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    cache_l = T.init_cache(cfg, 2, max_len)
    for i in range(s):
        logits_l, cache_l = T.decode_step(
            p, cache_l, toks[:, i : i + 1], jnp.int32(i), cfg
        )
    logits_f, cache_f = T.prefill(p, toks, cfg, max_len)
    np.testing.assert_allclose(
        np.asarray(logits_l[:, -1], np.float32),
        np.asarray(logits_f[:, -1], np.float32),
        rtol=0.15, atol=0.15,
    )
    tl = jnp.argmax(logits_l[:, -1], -1)[:, None].astype(jnp.int32)
    tf = jnp.argmax(logits_f[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(4):
        assert bool((tl == tf).all())
        logits_l, cache_l = T.decode_step(p, cache_l, tl, jnp.int32(s + i), cfg)
        logits_f, cache_f = T.decode_step(p, cache_f, tf, jnp.int32(s + i), cfg)
        tl = jnp.argmax(logits_l[:, -1], -1)[:, None].astype(jnp.int32)
        tf = jnp.argmax(logits_f[:, -1], -1)[:, None].astype(jnp.int32)


def test_vector_pos_matches_scalar_pos():
    """(B,) per-slot clocks with equal entries == the legacy scalar pos."""
    cfg = get_arch("qwen3_1_7b").smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    p = {"base": params["base"],
         "adapters": T._empty_adapters(params["adapters"])}
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    c1 = T.init_cache(cfg, 2, 8)
    c2 = T.init_cache(cfg, 2, 8)
    for i in range(4):
        l1, c1 = T.decode_step(p, c1, toks[:, i : i + 1], jnp.int32(i), cfg)
        l2, c2 = T.decode_step(
            p, c2, toks[:, i : i + 1], jnp.full((2,), i, jnp.int32), cfg
        )
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_temperature_without_key_samples():
    """temperature > 0 without a key must sample (deriving a key from
    the deployment key), not silently argmax."""
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    t1, _ = session.generate(prompt, gen_len=2, temperature=8.0)
    t2, _ = session.generate(prompt, gen_len=2, temperature=8.0)
    greedy, _ = session.generate(prompt, gen_len=2)
    # near-uniform sampling: the derived keys differ per call, and at
    # least one draw differs from the argmax path
    assert not np.array_equal(t1, t2)
    assert not (np.array_equal(t1, greedy) and np.array_equal(t2, greedy))


def test_key_with_zero_temperature_raises():
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab)
    with pytest.raises(ValueError, match="greedily"):
        session.generate(prompt, gen_len=2, key=jax.random.PRNGKey(0))
    engine = ServeEngine(session, max_slots=1, max_len=8)
    with pytest.raises(ValueError, match="greedily"):
        engine.submit(prompt[0], max_new=2, key=jax.random.PRNGKey(0))


def test_engine_rejects_oversized_request_and_encdec():
    cfg = get_arch("qwen3_1_7b").smoke
    session = Deployment.program(cfg, 0).serve()
    engine = ServeEngine(session, max_slots=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        engine.submit(np.zeros(6, np.int32), max_new=4)
    enc_cfg = get_arch("seamless_m4t_large_v2").smoke
    enc_session = Deployment.program(enc_cfg, 0).serve()
    with pytest.raises(NotImplementedError):
        ServeEngine(enc_session)
