"""Non-ideality suite (ISSUE 7): fault generators are deterministic and
replayable, faults apply at code read-back so every backend and the
prepared/fused serve path see bitwise-identical faulty weights, stuck
cells survive drift, injection is idempotent, snapshot/restore replays
fault events, and ``Fleet.inject`` is bitwise N independent
``Deployment.inject`` runs."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import rram
from repro.core.calibrate import merge_adapters_for_serve
from repro.deploy import Deployment, serving
from repro.deploy.deployment import calibration_batch
from repro.faults import (
    FaultSpec,
    apply_fault_map,
    build_map,
    fault_recovery_study,
    iv_nonlinearity,
    retention,
    saturated,
    stuck_at,
)
from repro.fleet import Fleet
from repro.models import transformer as T
from repro import substrate


def _cfg():
    return get_arch("qwen3_1_7b").smoke


def _spec(kind, seed=3):
    return {
        "stuck_at": lambda: stuck_at(seed, rate=0.03),
        "saturated": lambda: saturated(seed, rate=0.10, cap_fraction=0.6),
        "retention": lambda: retention(seed, rate=0.10, retain=0.5),
        "iv_nonlinearity": lambda: iv_nonlinearity(1.5),
    }[kind]()


KINDS = ("stuck_at", "saturated", "retention", "iv_nonlinearity")


def _leaves(tree):
    return jax.tree_util.tree_leaves(
        tree, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb) and len(la) > 0
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -- generators ---------------------------------------------------------------


def test_specs_deterministic_and_json_round_trip():
    cfg = _cfg()
    dep = Deployment.program(cfg, 0, backend="codes")
    for kind in KINDS:
        spec = _spec(kind)
        again = FaultSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert again == spec
        m1 = build_map(dep.codes, spec, cfg.rram)
        m2 = build_map(dep.codes, again, cfg.rram)
        _assert_trees_equal(m1, m2)
        v1 = apply_fault_map(dep.codes, m1, cfg.rram)
        v2 = apply_fault_map(dep.codes, m2, cfg.rram)
        _assert_trees_equal(v1, v2)


def test_generator_validation():
    with pytest.raises(ValueError):
        stuck_at(0, rate=1.5)
    with pytest.raises(ValueError):
        saturated(0, rate=0.1, cap_fraction=0.0)
    with pytest.raises(ValueError):
        retention(0, rate=-0.1)
    with pytest.raises(ValueError):
        iv_nonlinearity(-1.0)
    with pytest.raises(ValueError):
        build_map(
            Deployment.program(_cfg(), 0).codes,
            FaultSpec(kind="nope", params=(("rate", 0.1),), key_data=(0, 1)),
            _cfg().rram,
        )


# -- read-back choke point: identical faulty view everywhere -----------------


@pytest.mark.parametrize("kind", KINDS)
def test_faulty_view_bitwise_identical_across_backends(kind):
    """All three backends derive their faulty weights from the SAME
    uint8 codes view — the read-back choke point makes parity bitwise
    by construction."""
    cfg = _cfg()
    spec = _spec(kind)
    deps = {
        b: Deployment.program(cfg, 0, backend=b).advance(50.0).inject(spec)
        for b in ("codes", "dequant", "codes_adc")
    }
    for b in ("dequant", "codes_adc"):
        _assert_trees_equal(deps["codes"].codes_view, deps[b].codes_view)
    # the dequant base is exactly the float read-back of the shared view
    w_view = deps["codes"].codes_view["body"][0]["mixer"]["q"]["w"]
    w_deq = deps["dequant"].base["body"][0]["mixer"]["q"]["w"]
    np.testing.assert_array_equal(
        np.asarray(rram.dequantize(w_view, dtype=w_deq.dtype)),
        np.asarray(w_deq),
    )
    # pristine codes untouched by injection
    _assert_trees_equal(deps["codes"].codes, deps["dequant"].codes)


@pytest.mark.parametrize("kind", KINDS)
def test_backend_forward_parity_under_faults(kind):
    """End-to-end forwards under faults stay within the established
    codes-vs-dequant kernel tolerance (the weights are bitwise shared;
    only accumulation order differs)."""
    cfg = _cfg()
    spec = _spec(kind)
    batch = calibration_batch(cfg, 2, 8)
    dep_c = Deployment.program(cfg, 0, backend="codes").advance(50.0)
    dep_d = Deployment.program(cfg, 0, backend="dequant").advance(50.0)
    dep_c.inject(spec)
    dep_d.inject(spec)
    outs = {}
    for name, dep in (("codes", dep_c), ("dequant", dep_d)):
        with serving.backend_scope(dep.backend, cfg):
            outs[name] = np.asarray(
                T.forward(
                    {"base": dep.base, "adapters": dep.adapters}, batch, cfg
                ).astype(jnp.float32)
            )
    rel = np.linalg.norm(outs["codes"] - outs["dequant"]) / np.linalg.norm(
        outs["dequant"]
    )
    assert rel < 0.05
    # the ADC-faithful chain runs on the same faulty view and stays finite
    assert np.isfinite(dep_c.logit_mse(batch))


@pytest.mark.parametrize("kind", ["stuck_at", "iv_nonlinearity"])
def test_prepared_serve_path_bitwise_under_faults(kind):
    """The serve-time prepared/fused tree built from the deployment's
    (pre-applied) faulty base is bitwise the tree built from PRISTINE
    codes through ``prepare_base_for_serve(faults=...)`` — the fast
    path cannot drift from the raw backends under faults."""
    cfg = _cfg()
    dep = Deployment.program(cfg, 0, backend="codes").advance(50.0)
    dep.inject(_spec(kind))
    merged = merge_adapters_for_serve(dep.base, dep.adapters)
    prep_applied = substrate.prepare_base_for_serve(dep.base, merged, cfg)
    prep_routed = substrate.prepare_base_for_serve(
        dep.codes, merged, cfg, faults=dep._fault_map
    )
    _assert_trees_equal(prep_applied, prep_routed)
    # and the session built on it serves
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, cfg.vocab)
    logits, _ = dep.serve().prefill(prompt, 6)
    assert np.isfinite(np.asarray(logits)).all()


# -- lifecycle semantics ------------------------------------------------------


def test_injection_idempotent_and_order_independent():
    cfg = _cfg()
    s1, s2 = _spec("stuck_at"), _spec("saturated", seed=9)
    a = Deployment.program(cfg, 0, backend="codes").inject([s1, s2])
    b = Deployment.program(cfg, 0, backend="codes").inject([s2, s1])
    _assert_trees_equal(a.codes_view, b.codes_view)
    a.inject(s1)  # re-injecting an already-present fault changes nothing
    _assert_trees_equal(a.codes_view, b.codes_view)


def test_stuck_cells_stay_pinned_through_drift():
    cfg = _cfg()
    spec = stuck_at(5, rate=0.05, lrs_fraction=1.0)  # all stuck at LRS
    dep = Deployment.program(cfg, 0, backend="codes").inject(spec)
    fmap = dep._fault_map
    path, lf = next(iter(sorted(fmap.leaves.items())))
    mask = np.asarray(lf.stuck_mask_pos)
    assert mask.any()

    def pinned(view):
        for p, xw in _walk_cw(view):
            if p == path:
                return np.asarray(xw.g_pos)[mask]
        raise AssertionError(path)

    cm = cfg.rram.code_max
    assert (pinned(dep.codes_view) == cm).all()
    dep.advance(200.0)  # drift moves the pristine codes...
    assert (pinned(dep.codes_view) == cm).all()  # ...the view stays pinned
    # and the pristine codes did NOT get pinned
    assert not (pinned(dep.codes) == cm).all()


def _walk_cw(tree):
    from repro.core.calibrate import _path_str

    out = []

    def visit(p, x):
        if isinstance(x, rram.CrossbarWeight):
            out.append((_path_str(p), x))
        return x

    jax.tree_util.tree_map_with_path(
        visit, tree, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
    return out


def test_snapshot_restore_replays_fault_events(tmp_path):
    cfg = _cfg()
    dep = Deployment.program(cfg, 0, backend="codes")
    dep.advance(24.0)
    dep.inject([_spec("stuck_at"), _spec("retention", seed=11)])
    dep.calibrate(2, steps=2, seq_len=8)
    dep.advance(12.0)
    dep.snapshot(str(tmp_path))

    restored = Deployment.restore(cfg, str(tmp_path))
    assert [s.to_dict() for s in restored.fault_specs] == [
        s.to_dict() for s in dep.fault_specs
    ]
    _assert_trees_equal(dep.codes, restored.codes)
    _assert_trees_equal(dep.codes_view, restored.codes_view)
    _assert_trees_equal(dep.adapters, restored.adapters)
    batch = calibration_batch(cfg, 2, 8)
    assert dep.logit_mse(batch) == restored.logit_mse(batch)


# -- fleet parity (acceptance) ------------------------------------------------


def test_fleet_inject_bitwise_matches_independent_deployments():
    """``Fleet.inject`` on N chips == N independent ``Deployment.inject``
    runs with the chip-folded specs, bitwise — and untouched chips stay
    bitwise pristine."""
    cfg = _cfg()
    n = 3
    fleet = Fleet.program(cfg, 0, n_chips=n, backend="codes")
    fleet.advance([100.0, 300.0, 6.0])
    spec = stuck_at(7, rate=0.04)
    ivs = iv_nonlinearity(1.2)
    fleet.inject(spec, chips=[0, 2])
    fleet.inject(ivs, chips=[1])
    hours = [100.0, 300.0, 6.0]
    for i in range(n):
        dep = Deployment.program(
            cfg, (fleet.teacher_key, fleet.chip_key(i)), backend="codes"
        )
        dep.advance(hours[i])
        if i in (0, 2):
            dep.inject(spec.for_chip(i))
        else:
            dep.inject(ivs)
        chip = fleet.chip(i)
        _assert_trees_equal(dep.codes, chip.codes)
        _assert_trees_equal(dep.codes_view, chip.codes_view)
        _assert_trees_equal(dep.base, chip.base)
    # served logits: fleet chip vs solo chip, bitwise
    prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 4), 0, cfg.vocab)
    dep0 = Deployment.program(
        cfg, (fleet.teacher_key, fleet.chip_key(0)), backend="codes"
    ).advance(100.0).inject(spec.for_chip(0))
    l_solo, _ = dep0.serve().prefill(prompt, 6)
    l_fleet, _ = fleet.serve(0).prefill(prompt, 6)
    np.testing.assert_array_equal(np.asarray(l_solo), np.asarray(l_fleet))


def test_fleet_snapshot_restore_replays_fault_events(tmp_path):
    cfg = _cfg()
    fleet = Fleet.program(cfg, 0, n_chips=3, backend="codes")
    fleet.advance([24.0, 168.0, 6.0])
    fleet.inject(stuck_at(7, rate=0.04), chips=[1])
    fleet.calibrate(2, steps=2, seq_len=8, chips=[0, 1])
    fleet.snapshot(str(tmp_path))

    restored = Fleet.restore(cfg, str(tmp_path))
    assert [
        (s.to_dict(), list(c)) for s, c in restored.fault_events
    ] == [(s.to_dict(), list(c)) for s, c in fleet.fault_events]
    _assert_trees_equal(fleet.codes, restored.codes)
    _assert_trees_equal(fleet.codes_view, restored.codes_view)
    np.testing.assert_array_equal(
        fleet.hard_fault_proxy(), restored.hard_fault_proxy()
    )


def test_fleet_hard_fault_proxy_separates_faults_from_drift():
    """The max-column-jump proxy fires on a stuck chip far above a
    merely drifted chip; the mean drift proxy cannot tell them apart as
    cleanly — that separation is what the scheduler's hard path keys
    on."""
    cfg = _cfg()
    fleet = Fleet.program(cfg, 0, n_chips=3)
    fleet.advance([50.0, 300.0, 0.0])
    fleet.inject(stuck_at(7, rate=0.05), chips=[0])
    hard = fleet.hard_fault_proxy()
    assert hard[0] > 2 * hard[1]  # stuck chip dominates heavy drift
    assert hard[2] == 0.0         # healthy chip reads zero


# -- codes_adc limits come from RramConfig (satellite) -----------------------


def test_backend_scope_rejects_conflicting_adc_options():
    cfg = _cfg()
    with pytest.raises(ValueError, match="single source of truth"):
        serving.backend_scope("codes_adc", cfg, adc_bits=3)
    with pytest.raises(ValueError, match="single source of truth"):
        serving.backend_scope("codes_adc", cfg, code_max=100)
    # matching explicit values and config-derived defaults are fine
    with serving.backend_scope(
        "codes_adc", cfg, adc_bits=cfg.rram.adc_bits
    ):
        name, opts = substrate.active_backend_key()
        assert name == "codes_adc"
        assert dict(opts)["code_max"] == cfg.rram.code_max
        assert dict(opts)["adc_bits"] == cfg.rram.adc_bits


def test_resolve_adc_limits_defaults_mirror_rram_config():
    from repro.substrate.backends import resolve_adc_limits

    assert resolve_adc_limits(None, None, None) == (255, 8)
    assert resolve_adc_limits(None, None, 3) == (255, 3)  # no cfg: explicit ok
    assert resolve_adc_limits(_cfg().rram, 255, None) == (255, 8)
    with pytest.raises(ValueError):
        resolve_adc_limits(_cfg().rram, 100, None)


# -- recovery study -----------------------------------------------------------


def test_study_calibration_improves_faulted_accuracy():
    res = fault_recovery_study(
        smoke=True, samples=2, steps=8, seq_len=8, hours=300.0,
        classes=["stuck_at"],
    )["stuck_at"]
    assert res["faulted_mse"] > res["clean_mse"]          # fault degrades
    assert res["calibrated_mse"] < res["faulted_mse"]     # DoRA recovers
    assert res["recovered_fraction"] > 0
