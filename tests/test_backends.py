"""Substrate backend parity: the whole zoo on resident uint8 codes.

Contract (ISSUE 1 acceptance): for one drifted deployment, the ``codes``
backend (fused Pallas kernel, interpret mode on CPU) and the ``dequant``
backend agree to programming-quantization tolerance end-to-end through
``launch/serve.py``, and ``rram_bytes`` is a real measurement of the
resident code arrays under codes mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import substrate
from repro.configs import get_arch
from repro.core import calibrate as C
from repro.core import dora, rram
from repro.launch import serve
from repro.models import transformer as T


def _programmed_pair(arch_id, seed=0):
    """Same programming event in both substrate representations."""
    cfg = get_arch(arch_id).smoke
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    key = jax.random.PRNGKey(seed + 1)
    codes = C.program_model(params["base"], cfg.rram, key, mode="codes")
    floats = C.program_model(params["base"], cfg.rram, key, mode="dequant")
    return cfg, params, codes, floats


# -- registry ----------------------------------------------------------------


def test_backend_registry_and_context():
    assert set(substrate.available_backends()) >= {
        "dequant", "codes", "codes_adc"
    }
    assert substrate.active_backend_name() == substrate.DEFAULT_BACKEND
    with substrate.use_backend("codes_adc"):
        assert substrate.active_backend_name() == "codes_adc"
    assert substrate.active_backend_name() == substrate.DEFAULT_BACKEND
    with pytest.raises(KeyError):
        substrate.get_backend("analog_dreams")
    with pytest.raises(KeyError):
        with substrate.use_backend("analog_dreams"):
            pass


# -- single-linear parity ----------------------------------------------------


def _linear_fixture(d=200, n=150, r=8, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d, n)) * 0.05
    rcfg = rram.RramConfig(relative_drift=0.1)
    xw = rram.programmed_codes(w, rcfg, jax.random.fold_in(key, 1))
    acfg = dora.AdapterConfig(rank=r)
    ad = dora.init_adapter(
        jax.random.fold_in(key, 2), d, n, acfg, w_base=rram.dequantize(xw)
    )
    ad["lora_b"] = jax.random.normal(jax.random.fold_in(key, 3), (r, n)) * 0.02
    x = jax.random.normal(jax.random.fold_in(key, 4), (7, d), jnp.float32)
    return x, xw, ad, acfg


def test_codes_matches_dequant_on_same_codes():
    """Same resident codes, two backends: only kernel numerics differ."""
    x, xw, ad, acfg = _linear_fixture()
    y_codes = substrate.crossbar_linear(x, xw, ad, acfg, backend="codes")
    y_deq = substrate.crossbar_linear(x, xw, ad, acfg, backend="dequant")
    np.testing.assert_allclose(
        np.asarray(y_codes), np.asarray(y_deq), rtol=1e-4, atol=1e-4
    )


def test_codes_backend_no_adapter_is_plain_crossbar():
    x, xw, _, acfg = _linear_fixture()
    y = substrate.crossbar_linear(x, xw, None, acfg, backend="codes")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ rram.dequantize(xw)),
        rtol=1e-4, atol=1e-4,
    )


def test_codes_adc_backend_close_to_codes():
    x, xw, ad, acfg = _linear_fixture(d=256, n=128)
    y_adc = substrate.crossbar_linear(x, xw, ad, acfg, backend="codes_adc")
    y_codes = substrate.crossbar_linear(x, xw, ad, acfg, backend="codes")
    scale = float(jnp.abs(y_codes).max()) + 1e-9
    rel = np.abs(np.asarray(y_adc - y_codes)) / scale
    assert rel.max() < 0.05  # ADC quantization noise, not a different answer


def test_use_backend_options_reach_the_adc():
    """RramConfig plumbing: a coarser ADC (fewer bits) must visibly
    change the codes_adc output — the options are not decorative."""
    x, xw, ad, acfg = _linear_fixture(d=256, n=128)
    with substrate.use_backend("codes_adc", adc_bits=3):
        y_coarse = substrate.crossbar_linear(x, xw, ad, acfg)
    with substrate.use_backend("codes_adc"):
        y_default = substrate.crossbar_linear(x, xw, ad, acfg)
    assert float(jnp.abs(y_coarse - y_default).max()) > 0


def test_linear_dispatches_on_leaf_type():
    """models/layers.linear is the choke point: a CrossbarWeight base leaf
    routes to the substrate, a float leaf keeps the jnp path."""
    from repro.models import layers as L

    x, xw, ad, acfg = _linear_fixture()
    y_sub = L.linear(x, {"w": xw}, ad, acfg, backend="dequant")
    y_ref = dora.adapted_forward(x, rram.dequantize(xw), ad, acfg)
    np.testing.assert_array_equal(np.asarray(y_sub), np.asarray(y_ref))


# -- whole-model parity ------------------------------------------------------


def test_program_model_codes_returns_resident_leaves():
    cfg, params, codes, floats = _programmed_pair("qwen3_1_7b")
    # scan-stacked leaves keep their leading group axis in code space
    leaf = codes["body"][0]["mixer"]["q"]["w"]
    assert isinstance(leaf, rram.CrossbarWeight)
    assert leaf.g_pos.dtype == jnp.uint8 and leaf.g_neg.dtype == jnp.uint8
    assert leaf.g_pos.shape == floats["body"][0]["mixer"]["q"]["w"].shape
    # identical programming event: the float tree is the dequantized codes
    np.testing.assert_allclose(
        np.asarray(rram.dequantize(leaf, dtype=jnp.float32)),
        np.asarray(floats["body"][0]["mixer"]["q"]["w"], np.float32),
        rtol=0.01, atol=1e-4,  # bf16 read-back rounding only
    )


def test_rram_bytes_is_real_measurement_under_codes():
    cfg, params, codes, floats = _programmed_pair("qwen3_1_7b")
    measured = C.rram_bytes(codes)
    # measurement == summed byte size of the actual resident code arrays
    leaves = jax.tree_util.tree_leaves(
        codes, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
    expected = sum(
        l.g_pos.nbytes + l.g_neg.nbytes
        for l in leaves
        if isinstance(l, rram.CrossbarWeight)
    )
    assert measured == expected > 0
    # and it coincides with the dequant-mode 2-bytes/weight estimate
    assert measured == C.rram_bytes(floats)


@pytest.mark.parametrize(
    "arch_id,tol",
    [
        ("qwen3_1_7b", 0.05),
        # MoE: the drifted router sits near top-k ties, so the bf16 (float
        # deployment) vs f32 (code read-back) rounding can flip expert
        # choices for a few tokens — parity is looser but still tight
        # relative to the drift the calibration corrects.
        ("deepseek_v2_lite_16b", 0.10),
    ],
)
def test_forward_parity_codes_vs_dequant(arch_id, tol):
    """Dense and MoE (stacked expert codes) forwards agree across
    deployments to programming-quantization/bf16-read-back tolerance."""
    cfg, params, codes, floats = _programmed_pair(arch_id)
    merged_c = C.merge_adapters_for_serve(codes, params["adapters"])
    merged_f = C.merge_adapters_for_serve(floats, params["adapters"])
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
    }
    with substrate.use_backend("codes"):
        lc = T.forward({"base": codes, "adapters": merged_c}, batch, cfg)
    lf = T.forward({"base": floats, "adapters": merged_f}, batch, cfg)
    lc = np.asarray(lc, np.float32)
    lf = np.asarray(lf, np.float32)
    # relative Frobenius error: robust to near-zero logits
    rel = np.linalg.norm(lc - lf) / (np.linalg.norm(lf) + 1e-9)
    assert rel < tol, rel


def test_calibration_step_runs_on_resident_codes():
    """Training over a codes-resident student via the differentiable
    dequant backend: loss finite, adapters update, codes frozen."""
    from repro.core.calibrate import CalibState, make_calib_step
    from repro.optim.adam import AdamW, adamw_init

    cfg, params, codes, _ = _programmed_pair("qwen3_1_7b")
    state = CalibState(
        params["base"], codes, params["adapters"],
        adamw_init(params["adapters"]), jnp.zeros((), jnp.int32),
    )
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab)
    }
    step = make_calib_step(cfg, AdamW(lr=1e-3))
    with substrate.use_backend("dequant"):
        new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).sum()),
        state.adapters, new_state.adapters,
    )
    assert sum(jax.tree_util.tree_leaves(diff)) > 0
    # the array was never rewritten
    np.testing.assert_array_equal(
        np.asarray(new_state.student_base["body"][0]["mixer"]["q"]["w"].g_pos),
        np.asarray(codes["body"][0]["mixer"]["q"]["w"].g_pos),
    )


# -- end-to-end through launch/serve.py --------------------------------------


def test_serve_backend_parity_end_to_end():
    """launch/serve.py --backend codes vs --backend dequant on the same
    drifted deployment: per-step decode logits agree within tolerance."""
    cfg = get_arch("qwen3_1_7b").smoke
    p_codes = serve.load_student(cfg, seed=0, backend="codes")
    p_deq = serve.load_student(cfg, seed=0, backend="dequant")
    assert isinstance(
        p_codes["base"]["body"][0]["mixer"]["q"]["w"], rram.CrossbarWeight
    )
    prompt = jax.random.randint(jax.random.PRNGKey(0), (2, 6), 0, cfg.vocab)
    with serve.backend_scope("codes"):
        logits_c, _ = serve.prefill_and_cache(p_codes, prompt, cfg, 8)
    with serve.backend_scope("dequant"):
        logits_f, _ = serve.prefill_and_cache(p_deq, prompt, cfg, 8)
    lc = np.asarray(logits_c, np.float32)
    lf = np.asarray(logits_f, np.float32)
    rel = np.linalg.norm(lc - lf) / (np.linalg.norm(lf) + 1e-9)
    assert rel < 0.05, rel
    # the resident-code memory accounting is live on the serve path
    assert C.rram_bytes(p_codes["base"]) == C.rram_bytes(p_deq["base"]) > 0


def test_serve_generate_on_codes_backend():
    cfg = get_arch("qwen3_1_7b").smoke
    params = serve.load_student(cfg, seed=0, backend="codes")
    prompt = jax.random.randint(jax.random.PRNGKey(0), (2, 4), 0, cfg.vocab)
    with serve.backend_scope("codes"):
        toks, _ = serve.generate(params, prompt, cfg, gen_len=3)
    assert toks.shape == (2, 3)
