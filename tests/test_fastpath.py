"""Serve-time fast path (ISSUE 6): prepared operands, fused multi-leaf
launches, the decode GEMV dispatch and the int8 MMA path — all pinned
against the ``dequant`` reference across the model-zoo structures
(dense/GQA, MLA, MoE stacked expert codes, ragged shapes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import dora, rram
from repro.core.calibrate import merge_adapters_for_serve
from repro.deploy.deployment import Deployment
from repro.kernels import ops
from repro.substrate import (
    PreparedCrossbar,
    fuse_crossbars,
    prepare_base_for_serve,
    prepare_crossbar,
    prepared_ref_forward,
    rimc_linear_prepared,
)


def _mk_leaf(k, n, r, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (k, n)) * 0.05
    rcfg = rram.RramConfig(relative_drift=0.1)
    xw = rram.apply_drift(rram.program(w, rcfg), rcfg, k2)
    acfg = dora.AdapterConfig(rank=r)
    ad = dora.init_adapter(k3, k, n, acfg, w_base=rram.dequantize(xw))
    ad["lora_b"] = jax.random.normal(k3, (r, n)) * 0.02
    merged = merge_adapters_for_serve({"w": xw}, {"w": ad})["w"]
    return xw, merged, acfg


def _ref(x, xw, merged):
    w = rram.dequantize(xw)
    xf = x.astype(jnp.float32)
    y = xf @ w + (xf @ merged["lora_a"]) @ merged["lora_b"]
    return y * merged["dora_m_merged"][None, :]


# ---------------------------------------------------------------------------
# prepared leaves
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 2, 8, 70])
def test_prepared_leaf_matches_dequant_reference(m):
    xw, merged, acfg = _mk_leaf(200, 150, 8)
    prep = prepare_crossbar(xw, merged, acfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, 200)) * 0.5
    y = rimc_linear_prepared(x, prep)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_ref(x, xw, merged)), rtol=1e-4, atol=1e-4
    )
    # the dequant backend's view of the same prepared leaf agrees too
    np.testing.assert_allclose(
        np.asarray(prepared_ref_forward(x, prep)),
        np.asarray(_ref(x, xw, merged)), rtol=1e-4, atol=1e-4,
    )


def test_prepared_matches_unprepared_bitwise_same_tiles():
    """Preparation only moves work (padding) — with the same tile plan
    the kernel sees identical operands, so outputs are bitwise equal."""
    xw, merged, acfg = _mk_leaf(128, 128, 8)
    prep = prepare_crossbar(xw, merged, acfg, align=(1, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128)) * 0.5
    gamma = merged["dora_m_merged"].astype(jnp.float32)[None, :]
    y_unprep = ops.rimc_linear(x, xw, merged, gamma)
    y_prep = rimc_linear_prepared(x, prep)
    np.testing.assert_array_equal(np.asarray(y_unprep), np.asarray(y_prep))


def test_prepared_int8_within_quantization_tolerance():
    xw, merged, acfg = _mk_leaf(256, 128, 8)
    prep = prepare_crossbar(xw, merged, acfg, int8=True)
    assert prep.g_pos_s8 is not None and prep.g_pos_s8.dtype == jnp.int8
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256)) * 0.5
    y8 = rimc_linear_prepared(x, prep, accum="int8")
    y_ref = np.asarray(_ref(x, xw, merged))
    assert np.abs(np.asarray(y8) - y_ref).max() < 0.02 * np.abs(y_ref).max()


def test_fused_leaves_match_separate_launches():
    """gate+up fusion: one launch over concatenated N == two launches.
    Exact math — A factors concat over r, B factors block-diagonal."""
    acfg = dora.AdapterConfig(rank=4)
    xw1, m1, _ = _mk_leaf(128, 96, 4, seed=0)
    xw2, m2, _ = _mk_leaf(128, 160, 4, seed=1)
    fused = fuse_crossbars([(xw1, m1), (xw2, m2)], acfg)
    assert fused.splits == (96, 160) and fused.n == 256
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 128)) * 0.5
    y = rimc_linear_prepared(x, fused)
    y1 = np.asarray(_ref(x, xw1, m1))
    y2 = np.asarray(_ref(x, xw2, m2))
    np.testing.assert_allclose(
        np.asarray(y), np.concatenate([y1, y2], axis=1), rtol=1e-4, atol=1e-4
    )


def test_stacked_expert_codes_vmap_parity():
    """MoE-style stacked expert codes: vmap of the fused kernel over the
    expert axis matches the dequant einsum the MoE layer uses."""
    E, k, n, r = 3, 64, 96, 4
    leaves = [_mk_leaf(k, n, r, seed=s) for s in range(E)]
    xws = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *[l[0] for l in leaves],
        is_leaf=lambda v: isinstance(v, jax.Array),
    )
    x = jax.random.normal(jax.random.PRNGKey(9), (E, 2, k)) * 0.5
    acfg = leaves[0][2]

    def per_expert(xe, xwe, me):
        gamma = me["dora_m_merged"].astype(jnp.float32)[None, :]
        return ops.rimc_linear(xe, xwe, me, gamma)

    merged_stack = jax.tree_util.tree_map(
        lambda *ls: jnp.stack(ls), *[l[1] for l in leaves]
    )
    y = jax.vmap(per_expert)(x, xws, merged_stack)
    y_ref = np.stack([
        np.asarray(_ref(x[e], leaves[e][0], leaves[e][1])) for e in range(E)
    ])
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the no-pad guarantee (the _pad_to-inside-jit fix)
# ---------------------------------------------------------------------------


def _count_pad_eqns(jaxpr) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pad":
            total += 1
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", v)
            if hasattr(inner, "eqns"):
                total += _count_pad_eqns(inner)
    return total


def test_prepared_decode_call_has_no_pad_ops():
    """With operands prepared at serve time and the interpret-mode plan
    using true extents, the traced decode-shaped call contains zero pad
    primitives — the per-call jnp.pad copies are fully hoisted."""
    xw, merged, acfg = _mk_leaf(128, 128, 8)
    prep = prepare_crossbar(xw, merged, acfg, align=(1, 1))
    x = jnp.zeros((2, 128))
    jaxpr = jax.make_jaxpr(lambda xx: rimc_linear_prepared(xx, prep))(x)
    assert _count_pad_eqns(jaxpr.jaxpr) == 0


def test_unprepared_aligned_call_has_no_pad_ops():
    """Even unprepared, an interpret-mode call never pads: the autotuner
    plans tiles at the true extents."""
    xw, merged, acfg = _mk_leaf(200, 150, 8)
    gamma = merged["dora_m_merged"].astype(jnp.float32)[None, :]
    x = jnp.zeros((2, 200))
    jaxpr = jax.make_jaxpr(
        lambda xx: ops.rimc_linear(xx, xw, merged, gamma)
    )(x)
    assert _count_pad_eqns(jaxpr.jaxpr) == 0


# ---------------------------------------------------------------------------
# model-tree preparation + end-to-end parity
# ---------------------------------------------------------------------------


def test_prepare_base_walker_fuses_expected_groups():
    cfg = get_arch("qwen3-1.7b").smoke
    dep = Deployment.program(cfg, 0, backend="codes")
    merged = merge_adapters_for_serve(dep.base, dep.adapters)
    prep = prepare_base_for_serve(dep.base, merged, cfg)
    blocks = prep["blocks"] if "blocks" in prep else prep
    leaves = jax.tree_util.tree_leaves(
        prep, is_leaf=lambda v: isinstance(v, PreparedCrossbar)
    )
    assert any(isinstance(l, PreparedCrossbar) for l in leaves)

    def collect_keys(node, out):
        if isinstance(node, dict):
            out.update(node.keys())
            for v in node.values():
                collect_keys(v, out)
        elif isinstance(node, (list, tuple)):
            for v in node:
                collect_keys(v, out)

    keys: set = set()
    collect_keys(prep, keys)
    assert "_qkv" in keys and "_gate_up" in keys
    # fused members are consumed
    assert not ({"q", "k", "v"} & keys)


def test_prepare_base_walker_respects_structure_guards():
    # MLA (deepseek): q+kv_down and k_up+v_up fuse, never plain qkv
    cfg = get_arch("deepseek-v2-lite-16b").smoke
    dep = Deployment.program(cfg, 0, backend="codes")
    merged = merge_adapters_for_serve(dep.base, dep.adapters)
    prep = prepare_base_for_serve(dep.base, merged, cfg)
    keys: set = set()

    def collect(node):
        if isinstance(node, dict):
            keys.update(node.keys())
            for v in node.values():
                collect(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                collect(v)

    collect(prep)
    assert "_q_kvd" in keys and "_kup_vup" in keys and "_qkv" not in keys

    # cross-attention (seamless): q reads the decoder stream but k/v read
    # the encoder — the xattn subtree must never fuse qkv
    cfg_x = get_arch("seamless-m4t-large-v2").smoke
    dep_x = Deployment.program(cfg_x, 0, backend="codes")
    merged_x = merge_adapters_for_serve(dep_x.base, dep_x.adapters)
    prep_x = prepare_base_for_serve(dep_x.base, merged_x, cfg_x)

    def xattn_nodes(node, inside=False, found=None):
        found = [] if found is None else found
        if isinstance(node, dict):
            for key, v in node.items():
                if inside and key == "_qkv":
                    found.append(v)
                xattn_nodes(v, inside or key == "xattn", found)
        elif isinstance(node, (list, tuple)):
            for v in node:
                xattn_nodes(v, inside, found)
        return found

    assert xattn_nodes(prep_x) == []


@pytest.mark.parametrize(
    "arch,tol", [("qwen3-1.7b", 0.05), ("deepseek-v2-lite-16b", 0.10)]
)
def test_serve_prefill_parity_codes_vs_dequant(arch, tol):
    """The whole fast path end-to-end: prepared + fused + GEMV codes
    serving matches the dequant reference on prefill logits."""
    cfg = get_arch(arch).smoke
    prompt = jnp.asarray(
        np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab
    )
    logits = {}
    for backend in ("dequant", "codes"):
        dep = Deployment.program(cfg, 0, backend=backend)
        session = dep.serve()
        with session.scope():
            logits[backend], _ = session.prefill(prompt, 12)
    ld = np.asarray(logits["dequant"], np.float32)
    lc = np.asarray(logits["codes"], np.float32)
    rel = np.linalg.norm(ld - lc) / np.linalg.norm(ld)
    assert rel < tol
