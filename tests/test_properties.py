"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev dependency (requirements-dev.txt); suite degrades to skip",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dora, rram
from repro.models import layers as L


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(4, 64), k=st.integers(4, 64), seed=st.integers(0, 2 ** 16),
    scale=st.floats(0.01, 10.0),
)
def test_programming_quantization_error_bound(d, k, seed, scale):
    """|dequant(program(W)) - W| <= scale_col/2 elementwise, always."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, k)) * scale
    xw = rram.program(w, rram.RramConfig())
    err = np.abs(np.asarray(rram.dequantize(xw) - w))
    bound = np.asarray(xw.scale)[0] * 0.5 + 1e-6
    assert np.all(err <= bound)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(8, 48), k=st.integers(8, 48), r=st.integers(1, 8),
    seed=st.integers(0, 2 ** 16),
)
def test_dora_init_always_output_preserving(d, k, r, seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d, k)) * 0.2
    cfg = dora.AdapterConfig(rank=r, kind="dora")
    ad = dora.init_adapter(jax.random.fold_in(key, 1), d, k, cfg, w_base=w)
    x = jax.random.normal(jax.random.fold_in(key, 2), (4, d))
    np.testing.assert_allclose(
        np.asarray(dora.adapted_forward(x, w, ad, cfg)),
        np.asarray(x @ w), rtol=2e-4, atol=2e-4,
    )


@settings(max_examples=40, deadline=None)
@given(
    ticks=st.lists(st.floats(0.0, 96.0), min_size=1, max_size=10),
    drift=st.floats(0.01, 0.3),
)
def test_drift_increment_partition_invariance(ticks, drift):
    """Slicing a drift timeline into ANY tick partition accumulates the
    same total variance as one fused tick: independent Gaussian
    increments add in variance, so sum(increment^2) over an arbitrary
    partition of [0, T] equals drift_sigma(T)^2 — the invariant the
    fleet's heterogeneous per-chip clocks rely on."""
    cfg = rram.RramConfig(relative_drift=drift)
    total_hours, var, t = sum(ticks), 0.0, 0.0
    for h in ticks:
        inc = rram.drift_sigma_increment(cfg, t, h)
        var += inc * inc
        t += h
    np.testing.assert_allclose(
        np.sqrt(var), rram.drift_sigma(cfg, total_hours),
        rtol=1e-6, atol=1e-9,
    )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), drift=st.floats(0.01, 0.3))
def test_drift_preserves_shape_and_range(seed, drift):
    cfg = rram.RramConfig(relative_drift=drift)
    w = jax.random.normal(jax.random.PRNGKey(seed), (16, 16))
    xw = rram.apply_drift(rram.program(w, cfg), cfg, jax.random.PRNGKey(seed + 1))
    gp = np.asarray(xw.g_pos)
    assert gp.shape == (16, 16) and gp.min() >= 0 and gp.max() <= 255


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), s=st.integers(2, 16))
def test_rope_preserves_pairwise_norms(seed, s):
    """Rotary embedding is a rotation: per-pair L2 norms are invariant."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, s, 2, 8))
    pos = jnp.arange(s)[None]
    y = L.apply_rope(x, pos)
    x1, x2 = np.split(np.asarray(x), 2, axis=-1)
    y1, y2 = np.split(np.asarray(y), 2, axis=-1)
    np.testing.assert_allclose(
        x1 ** 2 + x2 ** 2, y1 ** 2 + y2 ** 2, rtol=2e-3, atol=2e-3
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_rmsnorm_output_rms_is_unit(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * 3.0
    p = L.init_rmsnorm(32)
    y = np.asarray(L.rms_norm(x, p), np.float32)
    rms = np.sqrt((y ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=2e-2)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2 ** 16), q=st.integers(1, 8), kv=st.integers(1, 16),
    w=st.sampled_from([None, 2, 4]),
)
def test_causal_mask_properties(seed, q, kv, w):
    from repro.models.attention import causal_mask
    if kv < q:
        kv = q
    m = np.asarray(causal_mask(q, kv, w))
    # each query attends to at least its own position
    assert m.shape == (q, kv)
    for i in range(q):
        assert m[i, kv - q + i]  # self
        assert not m[i, kv - q + i + 1 :].any()  # nothing in the future
        if w is not None:
            assert m[i].sum() <= w  # window bound


@settings(max_examples=12, deadline=None)
@given(
    d=st.integers(8, 32), k=st.integers(8, 32), seed=st.integers(0, 2 ** 16),
    kinds=st.lists(
        st.sampled_from(
            ["stuck_at", "saturated", "retention", "iv_nonlinearity"]
        ),
        min_size=2, max_size=2,
    ),
)
def test_fault_map_composition_order_independent_and_idempotent(
    d, k, seed, kinds
):
    """Fault-map composition is a lattice join: ``m1|m2`` and ``m2|m1``
    produce bitwise-identical faulty views, and ``m|m`` is ``m`` — so
    the ORDER faults are injected in never changes the read-back, and
    re-injecting an already-present fault is a no-op (what
    ``Deployment.inject`` idempotence rides on)."""
    from repro.faults import (
        apply_fault_map, build_map, iv_nonlinearity, retention, saturated,
        stuck_at,
    )

    cfg = rram.RramConfig()
    key = jax.random.PRNGKey(seed)
    tree = {
        "a": rram.program(jax.random.normal(key, (d, k)) * 0.2, cfg),
        "b": rram.program(
            jax.random.normal(jax.random.fold_in(key, 1), (k, d)) * 0.2, cfg
        ),
    }

    def mk(kind, s):
        return {
            "stuck_at": lambda: stuck_at(s, rate=0.1),
            "saturated": lambda: saturated(s, rate=0.2, cap_fraction=0.6),
            "retention": lambda: retention(s, rate=0.2, retain=0.5),
            "iv_nonlinearity": lambda: iv_nonlinearity(1.0 + 0.1 * (s % 7)),
        }[kind]()

    m1 = build_map(tree, mk(kinds[0], seed + 1), cfg)
    m2 = build_map(tree, mk(kinds[1], seed + 2), cfg)

    def codes(view):
        return [
            np.asarray(g)
            for xw in jax.tree_util.tree_leaves(
                view, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
            )
            for g in (xw.g_pos, xw.g_neg)
        ]

    ab = codes(apply_fault_map(tree, m1.compose(m2), cfg))
    ba = codes(apply_fault_map(tree, m2.compose(m1), cfg))
    for x, y in zip(ab, ba):
        np.testing.assert_array_equal(x, y)  # commutative

    once = codes(apply_fault_map(tree, m1, cfg))
    twice = codes(apply_fault_map(tree, m1.compose(m1), cfg))
    for x, y in zip(once, twice):
        np.testing.assert_array_equal(x, y)  # idempotent join

    if all(kd in ("stuck_at", "saturated") for kd in kinds):
        # pin/clamp classes are idempotent under literal re-APPLICATION
        # too (retention/iv re-bend the already-bent codes, which is why
        # views always derive from pristine codes, never from views)
        m = m1.compose(m2)
        v1 = apply_fault_map(tree, m, cfg)
        for x, y in zip(codes(apply_fault_map(v1, m, cfg)), codes(v1)):
            np.testing.assert_array_equal(x, y)


# -- calibration registry stability metrics (ISSUE 8) ------------------------


_samples = st.lists(
    st.floats(-100.0, 100.0, allow_nan=False), min_size=8, max_size=256
)


@settings(max_examples=40, deadline=None)
@given(a=_samples, b=_samples)
def test_jsd_symmetric_and_bounded(a, b):
    from repro.registry import jensen_shannon

    a, b = np.asarray(a), np.asarray(b)
    ab = jensen_shannon(a, b)
    ba = jensen_shannon(b, a)
    assert ab == pytest.approx(ba, abs=1e-12)  # symmetric
    assert 0.0 <= ab <= 1.0 + 1e-12            # base-2: bounded


@settings(max_examples=40, deadline=None)
@given(a=_samples)
def test_jsd_zero_on_identical(a):
    from repro.registry import jensen_shannon

    a = np.asarray(a)
    assert jensen_shannon(a, a) == pytest.approx(0.0, abs=1e-12)


@settings(max_examples=40, deadline=None)
@given(a=_samples, b=_samples)
def test_percentile_drift_nonnegative_zero_on_self(a, b):
    from repro.registry import stability_metrics

    a, b = np.asarray(a), np.asarray(b)
    m = stability_metrics(a, b)
    for v in m.drifts().values():
        assert v >= 0.0
    on_self = stability_metrics(a, a)
    for name, v in on_self.drifts().items():
        assert v == pytest.approx(0.0, abs=1e-9), name
    assert on_self.is_stable


@settings(max_examples=40, deadline=None)
@given(
    a=_samples, b=_samples,
    t=st.floats(1e-6, 1.0), bumps=st.lists(
        st.floats(0.0, 1.0), min_size=5, max_size=5
    ),
)
def test_is_stable_monotone_in_thresholds(a, b, t, bumps):
    """Loosening any threshold never flips stable -> unstable."""
    from repro.registry import (
        StabilityThresholds, is_stable_under, stability_metrics,
    )

    m = stability_metrics(np.asarray(a), np.asarray(b))
    lo = StabilityThresholds(apd=t, srd=t, jsd=t, median=t, iqr=t)
    hi = StabilityThresholds(
        apd=t + bumps[0], srd=t + bumps[1], jsd=t + bumps[2],
        median=t + bumps[3], iqr=t + bumps[4],
    )
    if is_stable_under(m, lo):
        assert is_stable_under(m, hi)
