"""Paper-faithful CNN reproduction at CI scale: drift degrades, feature-
based DoRA calibration restores (the paper's headline mechanism)."""
import dataclasses

import pytest as _pytest

# teacher-training fixture + calibration loops: fast lane skips these
pytestmark = _pytest.mark.slow

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import repro_experiments as rx
from repro.core import resnet
from repro.core.dora import AdapterConfig
from repro.core.resnet import ResnetConfig

CFG = ResnetConfig(depth=8, width=8, classes=8, image_size=16,
                   adapter=AdapterConfig(rank=2, kind="dora"))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    k_data, k_teacher = jax.random.split(key)
    train = resnet.procedural_dataset(k_data, 512, CFG, noise=0.3)
    test = resnet.procedural_dataset(jax.random.fold_in(k_data, 7), 512, CFG,
                                     noise=0.3)
    teacher = rx.train_teacher(k_teacher, CFG, *train, epochs=6, batch=64)
    return teacher, train, test


def test_teacher_learns(setup):
    teacher, train, test = setup
    acc = resnet.accuracy(teacher, *test, CFG)
    assert acc > 0.7  # procedural task is learnable well above 1/8 chance


def test_drift_degrades_accuracy(setup):
    teacher, train, test = setup
    base_acc = resnet.accuracy(teacher, *test, CFG)
    student = rx.make_student(teacher, 0.25, jax.random.PRNGKey(5))
    drift_acc = resnet.accuracy(student, *test, CFG)
    assert drift_acc < base_acc - 0.05


def test_feature_dora_calibration_restores(setup):
    teacher, train, test = setup
    teacher_acc = resnet.accuracy(teacher, *test, CFG)
    student = rx.make_student(teacher, 0.25, jax.random.PRNGKey(5))
    drift_acc = resnet.accuracy(student, *test, CFG)
    adapters = resnet.init_adapters(jax.random.PRNGKey(6), student, CFG)
    # paper protocol: 10 calibration samples
    cal = train[0][:10]
    adapters, losses = rx.feature_calibrate(
        teacher, student, adapters, cal, CFG, epochs=10, batch=10, lr=5e-3
    )
    calib_acc = resnet.accuracy(student, *test, CFG, adapters=adapters)
    assert losses[-1] < losses[0]  # MSE decreased
    # restores a substantial part of the drift-induced gap
    assert calib_acc > drift_acc + 0.3 * (teacher_acc - drift_acc)


def test_adapter_fraction_is_small(setup):
    teacher, _, _ = setup
    adapters = resnet.init_adapters(jax.random.PRNGKey(0), teacher, CFG)
    n_ad = sum(x.size for x in jax.tree_util.tree_leaves(adapters))
    n_base = sum(x.size for x in jax.tree_util.tree_leaves(teacher))
    assert n_ad / n_base < 0.35  # tiny CNN; paper gets 2.34% on ResNet-50


def test_bn_stats_frozen_during_calibration(setup):
    """The paper's 'no BN update' property: calibration touches only
    adapters; teacher/student BN tensors are not inputs to the optimizer."""
    teacher, train, _ = setup
    student = rx.make_student(teacher, 0.2, jax.random.PRNGKey(5))
    before = np.asarray(student["stem_bn"]["mean"])
    adapters = resnet.init_adapters(jax.random.PRNGKey(6), student, CFG)
    rx.feature_calibrate(
        teacher, student, adapters, train[0][:4], CFG, epochs=2, batch=4
    )
    np.testing.assert_array_equal(before, np.asarray(student["stem_bn"]["mean"]))
