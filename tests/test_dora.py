"""DoRA/LoRA adapters: init semantics, norms, merge, quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dora


def _setup(kind="dora", r=4, d=32, k=24, seed=0):
    key = jax.random.PRNGKey(seed)
    kw, ka, kx = jax.random.split(key, 3)
    w = jax.random.normal(kw, (d, k)) * 0.1
    cfg = dora.AdapterConfig(rank=r, kind=kind)
    ad = dora.init_adapter(ka, d, k, cfg, w_base=w)
    x = jax.random.normal(kx, (8, d))
    return w, ad, x, cfg


def test_init_is_output_preserving_dora():
    """Algorithm 2 line 2: B=0 and M=||W|| -> initial output == X@W."""
    w, ad, x, cfg = _setup("dora")
    y = dora.adapted_forward(x, w, ad, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5, atol=1e-5)


def test_init_is_output_preserving_lora():
    w, ad, x, cfg = _setup("lora")
    y = dora.adapted_forward(x, w, ad, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)


def test_column_norm_matches_direct():
    w, ad, x, cfg = _setup()
    a = jax.random.normal(jax.random.PRNGKey(5), ad["lora_a"].shape) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(6), ad["lora_b"].shape) * 0.3
    direct = jnp.linalg.norm(w + a @ b, axis=0)
    fast = dora.column_norm(w, a, b)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(direct), rtol=1e-4)


def test_dora_forward_matches_weight_space_definition():
    """Y = M * normalize_col(W + AB) applied to X — the weight-space DoRA."""
    w, ad, x, cfg = _setup()
    ad = dict(ad)
    ad["lora_b"] = jax.random.normal(jax.random.PRNGKey(7), ad["lora_b"].shape) * 0.2
    y = dora.adapted_forward(x, w, ad, cfg)
    w_adapt = w + ad["lora_a"] @ ad["lora_b"]
    norm = jnp.linalg.norm(w_adapt, axis=0)
    y_ref = x @ (w_adapt * (ad["dora_m"] / norm)[None, :])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-4)


def test_merge_magnitude_freezes_norm():
    w, ad, x, cfg = _setup()
    ad["lora_b"] = jax.random.normal(jax.random.PRNGKey(8), ad["lora_b"].shape) * 0.2
    merged = dora.merge_magnitude(w, ad, cfg)
    y_live = dora.adapted_forward(x, w, ad, cfg)
    y_merged = dora.adapted_forward(x, w, ad, cfg, merged_norm=merged)
    np.testing.assert_allclose(np.asarray(y_live), np.asarray(y_merged), rtol=1e-5)


def test_magnitude_only_controls_scale():
    """M scales output columns without changing direction (the DoRA
    property LoRA lacks)."""
    w, ad, x, cfg = _setup()
    y1 = dora.adapted_forward(x, w, ad, cfg)
    ad2 = dict(ad)
    ad2["dora_m"] = ad["dora_m"] * 2.0
    y2 = dora.adapted_forward(x, w, ad2, cfg)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1) * 2.0, rtol=1e-5)


def test_param_ratio_eq7():
    # paper quotes r=1: ResNet-20 4.46%, ResNet-50 0.585% (model-level);
    # eq. 7 itself is per-layer: (d*r + r*k + k) / (d*k)
    assert dora.param_ratio(100, 100, 1) == pytest.approx(300 / 10000)
    assert dora.param_ratio(1000, 1000, 4) > dora.param_ratio(1000, 1000, 1)
    # larger models -> smaller relative overhead (paper §IV-C)
    assert dora.param_ratio(4608, 512, 1) < dora.param_ratio(144, 16, 1)


def test_adapter_param_count():
    cfg = dora.AdapterConfig(rank=3, kind="dora")
    assert dora.adapter_param_count(10, 20, cfg) == 10 * 3 + 3 * 20 + 20
    cfg = dora.AdapterConfig(rank=3, kind="lora")
    assert dora.adapter_param_count(10, 20, cfg) == 10 * 3 + 3 * 20
    assert dora.adapter_param_count(10, 20, dora.AdapterConfig(kind="none")) == 0


def test_int8_adapter_quantization_roundtrip():
    w, ad, x, cfg = _setup()
    ad["lora_b"] = jax.random.normal(jax.random.PRNGKey(9), ad["lora_b"].shape) * 0.2
    q = dora.quantize_adapter_int8(ad)
    deq = dora.dequantize_adapter_int8(q)
    for name in ad:
        err = np.abs(np.asarray(deq[name]) - np.asarray(ad[name])).max()
        scale = float(q[name][1])
        assert err <= scale * 0.51
    y = dora.adapted_forward(x, w, ad, cfg)
    yq = dora.adapted_forward(x, w, deq, cfg)
    assert np.abs(np.asarray(y - yq)).max() / (np.abs(np.asarray(y)).max()) < 0.05


def test_conv_adapter_init_preserving():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (3, 3, 8, 16)) * 0.1
    cfg = dora.AdapterConfig(rank=2, kind="dora")
    ad = dora.init_conv_adapter(jax.random.PRNGKey(1), 3, 3, 8, 16, cfg, w_base=w)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 8))
    y = dora.adapted_conv_forward(x, w, ad, cfg)
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    y_ref = jax.lax.conv_general_dilated(x, w, (1, 1), "SAME", dimension_numbers=dn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-5)


def test_gradients_flow_through_magnitude_and_direction():
    w, ad, x, cfg = _setup()

    def loss(ad):
        y = dora.adapted_forward(x, w, ad, cfg)
        return jnp.sum(y * y)

    g = jax.grad(loss)(ad)
    assert float(jnp.abs(g["dora_m"]).sum()) > 0
    # B is zero at init but its gradient is nonzero (XA != 0); A's gradient
    # is exactly zero at init (every path through A carries a factor of B —
    # the standard LoRA warm-start property) and opens up once B moves.
    assert float(jnp.abs(g["lora_b"]).sum()) > 0
    assert float(jnp.abs(g["lora_a"]).sum()) == 0
    ad2 = dict(ad)
    ad2["lora_b"] = ad["lora_b"] - 1e-2 * g["lora_b"]
    g2 = jax.grad(loss)(ad2)
    assert float(jnp.abs(g2["lora_a"]).sum()) > 0
