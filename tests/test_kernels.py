"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape/dtype
sweeps and hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a dev dependency (requirements-dev.txt): only the
# property tests skip without it — the example-based kernel parity suite
# (the ISSUE 6 regression gate) must run everywhere.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

    def given(**kwargs):  # noqa: D103 - stub so decorators still apply
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(**kwargs):
        return lambda fn: fn

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

from repro.core import dora, rram
from repro.kernels import autotune, ops, ref
from repro.kernels.dora_linear import dora_linear, dora_linear_gemv
from repro.kernels.crossbar_mvm import crossbar_mvm


def _mk(m, k, n, r, seed=0, drift=0.1, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w = jax.random.normal(k1, (k, n)) * 0.05
    rcfg = rram.RramConfig(relative_drift=drift)
    xw = rram.apply_drift(rram.program(w, rcfg), rcfg, k2)
    ad = dora.init_adapter(
        k3, k, n, dora.AdapterConfig(rank=r), w_base=rram.dequantize(xw)
    )
    ad["lora_b"] = jax.random.normal(k4, (r, n)) * 0.02
    x = (jax.random.normal(k2, (m, k)) * 0.5).astype(dtype)
    return x, xw, ad


@pytest.mark.parametrize(
    "m,k,n,r",
    [
        (128, 128, 128, 4),
        (128, 256, 384, 8),
        (256, 512, 128, 16),
        (128, 128, 256, 64),
    ],
)
def test_dora_linear_vs_oracle_shapes(m, k, n, r):
    x, xw, ad = _mk(m, k, n, r)
    gamma = ops.dora_gamma(xw, ad)
    y = dora_linear(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        ad["lora_a"], ad["lora_b"], gamma, interpret=True,
    )
    y_ref = ref.dora_linear_ref(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1),
        ad["lora_a"], ad["lora_b"], gamma,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rimc_linear_wrapper_padding_and_dtypes(dtype):
    # ragged shapes exercise the padding path
    x, xw, ad = _mk(70, 200, 150, 8, dtype=dtype)
    y = ops.rimc_linear(x, xw, ad)
    w = rram.dequantize(xw)
    acfg = dora.AdapterConfig(rank=8)
    merged = dora.merge_magnitude(w, ad, acfg)
    y_ref = dora.adapted_forward(
        x.astype(jnp.float32), w, ad, acfg, merged_norm=merged
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (128, 512, 256)])
def test_crossbar_mvm_vs_tile_oracle(m, k, n):
    """Same tiling, DAC reference and ADC behaviour as the oracle; only
    f32 accumulation-order rounding (~1e-7) may differ across K tiles."""
    x, xw, _ = _mk(m, k, n, 4)
    y = crossbar_mvm(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        interpret=True,
    )
    y_ref = ref.crossbar_mvm_ref(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1)
    )
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-6
    )


def test_crossbar_mvm_adc_close_to_ideal():
    x, xw, _ = _mk(128, 512, 128, 4)
    y = ops.rimc_mvm_adc(x, xw)
    ideal = x @ rram.dequantize(xw)
    rel = np.abs(np.asarray(y - ideal)) / (np.abs(np.asarray(ideal)).max() + 1e-9)
    assert rel.max() < 0.05


def test_dora_linear_zero_adapter_is_crossbar_matmul():
    x, xw, ad = _mk(128, 128, 128, 4)
    ad["lora_b"] = jnp.zeros_like(ad["lora_b"])
    gamma = jnp.ones((1, 128), jnp.float32)
    y = dora_linear(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        ad["lora_a"], ad["lora_b"], gamma, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ rram.dequantize(xw)), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    mi=st.integers(1, 3), ki=st.integers(1, 3), ni=st.integers(1, 3),
    r=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2 ** 16),
)
def test_property_dora_linear_matches_oracle(mi, ki, ni, r, seed):
    m, k, n = 128 * mi, 128 * ki, 128 * ni
    x, xw, ad = _mk(m, k, n, r, seed=seed)
    gamma = ops.dora_gamma(xw, ad)
    y = dora_linear(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        ad["lora_a"], ad["lora_b"], gamma, interpret=True,
    )
    y_ref = ref.dora_linear_ref(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1),
        ad["lora_a"], ad["lora_b"], gamma,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# decode-shaped GEMV variant, int8 MMA, autotuner (ISSUE 6)
# ---------------------------------------------------------------------------


def _rimc_ref(x, xw, ad):
    w = rram.dequantize(xw)
    acfg = dora.AdapterConfig(rank=ad["lora_a"].shape[-1])
    merged = dora.merge_magnitude(w, ad, acfg)
    return dora.adapted_forward(
        x.astype(jnp.float32), w, ad, acfg, merged_norm=merged
    )


@pytest.mark.parametrize("m", [1, 2, 8])
@pytest.mark.parametrize("k,n", [(128, 128), (200, 150)])
def test_rimc_linear_decode_shapes_vs_oracle(m, k, n):
    """Small-M calls (the decode hot path) dispatch the GEMV variant —
    incl. ragged K/N where the wrapper pads on TPU and not on CPU."""
    x, xw, ad = _mk(m, k, n, 8)
    y = ops.rimc_linear(x, xw, ad)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(_rimc_ref(x, xw, ad)), rtol=1e-4, atol=1e-4
    )


def test_dora_linear_gemv_matches_tiled_kernel():
    """Same operands, same K split: the single-M-block GEMV launcher and
    the tiled launcher compute identical sums."""
    x, xw, ad = _mk(8, 256, 128, 8)
    gamma = ops.dora_gamma(xw, ad)
    scale = xw.scale.reshape(1, -1).astype(jnp.float32)
    xp = jnp.pad(x, ((0, 120), (0, 0)))  # tiled kernel needs M % 128 == 0
    y_tiled = dora_linear(
        xp, xw.g_pos, xw.g_neg, scale, ad["lora_a"], ad["lora_b"], gamma,
        interpret=True,
    )[:8]
    y_gemv = dora_linear_gemv(
        x, xw.g_pos, xw.g_neg, scale, ad["lora_a"], ad["lora_b"], gamma,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y_gemv), np.asarray(y_tiled), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("m", [2, 128])
def test_rimc_linear_int8_accum_tolerance(m):
    """Integer MMA path: s8 activation quantization bounds the error at
    <2% of the output absmax (codes dequant stays exact — the u8->s8
    offset recode cancels in the differential combine)."""
    x, xw, ad = _mk(m, 256, 128, 8)
    y8 = ops.rimc_linear(x, xw, ad, accum="int8")
    y_ref = np.asarray(_rimc_ref(x, xw, ad))
    err = np.abs(np.asarray(y8) - y_ref).max()
    assert err < 0.02 * np.abs(y_ref).max() + 1e-6


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([2, 70, 128]),
    kn=st.sampled_from([(128, 128), (200, 150)]),
    tiles=st.sampled_from([(None, None, None), (128, 128, 128), (8, 64, 32)]),
    seed=st.integers(0, 2 ** 16),
)
def test_property_rimc_linear_block_size_invariant(m, kn, tiles, seed):
    """The output must not depend on tile choice: explicit (bm, bn, bk)
    overrides agree with the autotuned plan (operands pad to any
    choice)."""
    k, n = kn
    x, xw, ad = _mk(m, k, n, 8, seed=seed)
    bm, bn, bk = tiles
    y = ops.rimc_linear(x, xw, ad, bm=bm, bn=bn, bk=bk)
    y_auto = ops.rimc_linear(x, xw, ad)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_auto), rtol=1e-4, atol=1e-5
    )


def test_autotune_interpret_plans_never_pad():
    for m, k, n, r in [(1, 64, 64, 4), (2, 200, 150, 8), (128, 256, 384, 16)]:
        plan = autotune.select_tiles(m, k, n, r, interpret=True)
        assert (plan.m_pad, plan.k_pad, plan.n_pad) == (m, k, n)
        assert plan.gemv  # grid has no M axis: whole M is one block


def test_autotune_tpu_plans_aligned_and_within_budget():
    for m, k, n, r, int8 in [
        (2, 2048, 4096, 8, False), (512, 4096, 4096, 16, False),
        (8, 1024, 1024, 8, True),
    ]:
        plan = autotune.select_tiles(m, k, n, r, interpret=False, int8=int8)
        sublane = 32 if int8 else 8
        assert plan.bm % sublane == 0 and plan.bn % 128 == 0
        assert plan.k_pad % plan.bk == 0 and plan.n_pad % plan.bn == 0
        assert plan.m_pad % plan.bm == 0
        assert autotune._vmem_bytes(
            plan.bm, plan.bn, plan.bk, r, int8
        ) <= autotune.VMEM_BUDGET_BYTES
        if m <= autotune.GEMV_MAX_M:
            assert plan.gemv and plan.bm < 128
