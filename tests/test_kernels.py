"""Pallas kernels vs pure-jnp oracles (interpret mode), with shape/dtype
sweeps and hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev dependency (requirements-dev.txt); suite degrades to skip",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dora, rram
from repro.kernels import ops, ref
from repro.kernels.dora_linear import dora_linear
from repro.kernels.crossbar_mvm import crossbar_mvm


def _mk(m, k, n, r, seed=0, drift=0.1, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w = jax.random.normal(k1, (k, n)) * 0.05
    rcfg = rram.RramConfig(relative_drift=drift)
    xw = rram.apply_drift(rram.program(w, rcfg), rcfg, k2)
    ad = dora.init_adapter(
        k3, k, n, dora.AdapterConfig(rank=r), w_base=rram.dequantize(xw)
    )
    ad["lora_b"] = jax.random.normal(k4, (r, n)) * 0.02
    x = (jax.random.normal(k2, (m, k)) * 0.5).astype(dtype)
    return x, xw, ad


@pytest.mark.parametrize(
    "m,k,n,r",
    [
        (128, 128, 128, 4),
        (128, 256, 384, 8),
        (256, 512, 128, 16),
        (128, 128, 256, 64),
    ],
)
def test_dora_linear_vs_oracle_shapes(m, k, n, r):
    x, xw, ad = _mk(m, k, n, r)
    gamma = ops.dora_gamma(xw, ad)
    y = dora_linear(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        ad["lora_a"], ad["lora_b"], gamma, interpret=True,
    )
    y_ref = ref.dora_linear_ref(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1),
        ad["lora_a"], ad["lora_b"], gamma,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rimc_linear_wrapper_padding_and_dtypes(dtype):
    # ragged shapes exercise the padding path
    x, xw, ad = _mk(70, 200, 150, 8, dtype=dtype)
    y = ops.rimc_linear(x, xw, ad)
    w = rram.dequantize(xw)
    acfg = dora.AdapterConfig(rank=8)
    merged = dora.merge_magnitude(w, ad, acfg)
    y_ref = dora.adapted_forward(
        x.astype(jnp.float32), w, ad, acfg, merged_norm=merged
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (128, 512, 256)])
def test_crossbar_mvm_vs_tile_oracle(m, k, n):
    """Same tiling, DAC reference and ADC behaviour as the oracle; only
    f32 accumulation-order rounding (~1e-7) may differ across K tiles."""
    x, xw, _ = _mk(m, k, n, 4)
    y = crossbar_mvm(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        interpret=True,
    )
    y_ref = ref.crossbar_mvm_ref(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1)
    )
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), rtol=1e-4, atol=1e-6
    )


def test_crossbar_mvm_adc_close_to_ideal():
    x, xw, _ = _mk(128, 512, 128, 4)
    y = ops.rimc_mvm_adc(x, xw)
    ideal = x @ rram.dequantize(xw)
    rel = np.abs(np.asarray(y - ideal)) / (np.abs(np.asarray(ideal)).max() + 1e-9)
    assert rel.max() < 0.05


def test_dora_linear_zero_adapter_is_crossbar_matmul():
    x, xw, ad = _mk(128, 128, 128, 4)
    ad["lora_b"] = jnp.zeros_like(ad["lora_b"])
    gamma = jnp.ones((1, 128), jnp.float32)
    y = dora_linear(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        ad["lora_a"], ad["lora_b"], gamma, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ rram.dequantize(xw)), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    mi=st.integers(1, 3), ki=st.integers(1, 3), ni=st.integers(1, 3),
    r=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2 ** 16),
)
def test_property_dora_linear_matches_oracle(mi, ki, ni, r, seed):
    m, k, n = 128 * mi, 128 * ki, 128 * ni
    x, xw, ad = _mk(m, k, n, r, seed=seed)
    gamma = ops.dora_gamma(xw, ad)
    y = dora_linear(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1).astype(jnp.float32),
        ad["lora_a"], ad["lora_b"], gamma, interpret=True,
    )
    y_ref = ref.dora_linear_ref(
        x, xw.g_pos, xw.g_neg, xw.scale.reshape(1, -1),
        ad["lora_a"], ad["lora_b"], gamma,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
