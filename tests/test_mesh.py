"""Mesh-native lifecycle parity (tensor-parallel serve, mesh fleet
calibration, elastic re-mesh replay).

Gated on 8 visible devices: the tier-1 run sees 1 CPU device and skips
this file; the CI multi-device fast lane sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and runs it for
real. Everything here is BITWISE parity except the int8-compressed
gradient path, which is tolerance-bounded by construction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

SERVE_ARCHS = ["qwen3-1.7b", "deepseek-v2-lite-16b", "mixtral-8x22b"]


def _mesh(shape):
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(shape)


def _prompt(cfg, batch=2, length=6, seed=1):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (batch, length), 0,
                           cfg.vocab)
    )


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_sharded_serve_generate_bitwise(arch):
    """Dense, MLA and MoE smoke configs: greedy generation on a (1, 4)
    mesh is bitwise the single-device run, and the wrap policy actually
    sharded something (a fully-replicated tree would pass parity
    vacuously)."""
    from repro import deploy
    from repro.configs import get_arch

    cfg = get_arch(arch).smoke
    dep = deploy.Deployment.program(cfg, 0, backend="codes")
    prompt = jnp.asarray(_prompt(cfg))

    ref, _ = dep.serve().generate(prompt, gen_len=5)
    sess = dep.serve(mesh=_mesh((1, 4)))
    assert sess.shard_stats["sharded"] > 0, sess.shard_stats
    got, _ = sess.generate(prompt, gen_len=5)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_sharded_prefill_logits_bitwise():
    from repro import deploy
    from repro.configs import get_arch
    from repro.deploy import serving

    cfg = get_arch("qwen3-1.7b").smoke
    dep = deploy.Deployment.program(cfg, 0, backend="codes")
    prompt = jnp.asarray(_prompt(cfg))

    s0 = dep.serve()
    with s0.scope():
        ref, _ = serving.prefill_and_cache(s0.params, prompt, cfg, 32)
    mesh = _mesh((1, 4))
    s1 = dep.serve(mesh=mesh)
    with s1.scope():
        got, _ = serving.prefill_and_cache(
            s1.params, prompt, cfg, 32, mesh=mesh
        )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_mesh_serve_requires_codes_backend():
    from repro import deploy
    from repro.configs import get_arch

    cfg = get_arch("qwen3-1.7b").smoke
    dep = deploy.Deployment.program(cfg, 0, backend="dequant")
    with pytest.raises(ValueError, match="codes"):
        dep.serve(mesh=_mesh((1, 4)))


def _run_engine(session, prompts, *, remesh_at=None):
    from repro.deploy.engine import ServeEngine

    eng = ServeEngine(session, max_slots=2, max_len=32)
    reqs = [eng.submit(p, max_new=8) for p in prompts]
    plan = None
    n = 0
    while eng.step():
        n += 1
        if remesh_at is not None and n == remesh_at:
            plan = eng.remesh()  # degrade by one host: (2,4) -> (1,4)
    return [r.tokens for r in reqs], plan


def test_engine_remesh_replays_inflight_slots_exactly():
    """Mid-serve host loss on a (2, 4) mesh: the degraded engine's
    remaining stream is bitwise what an undisturbed single-device engine
    produces — replay reconstructed every in-flight slot exactly."""
    from repro import deploy
    from repro.configs import get_arch

    cfg = get_arch("qwen3-1.7b").smoke
    dep = deploy.Deployment.program(cfg, 0, backend="codes")
    prompts = [np.arange(4) % cfg.vocab, (np.arange(7) * 3) % cfg.vocab]

    ref, _ = _run_engine(dep.serve(), prompts)
    got, plan = _run_engine(
        dep.serve(mesh=_mesh((2, 4))), prompts, remesh_at=3
    )
    assert plan is not None and plan.failed_hosts == 1
    assert plan.new_mesh_shape == (1, 4)
    assert ref == got


def test_fleet_mesh_calibration_bitwise_uncompressed():
    """Chip axis sharded over "data": chips are independent batch rows,
    so the GSPMD run must reproduce single-device losses AND adapters
    bitwise."""
    from repro.configs import get_arch
    from repro.fleet.fleet import Fleet

    cfg = get_arch("qwen3-1.7b").smoke

    def run(mesh=None, grad_compress=False):
        fleet = Fleet.program(cfg, 0, n_chips=4, backend="dequant")
        fleet.advance(24.0)
        rep = fleet.calibrate(steps=3, mesh=mesh, grad_compress=grad_compress)
        return rep, fleet

    rep0, f0 = run()
    rep1, f1 = run(mesh=_mesh((2, 4)))
    np.testing.assert_array_equal(rep0.losses, rep1.losses)
    for a, b in zip(jax.tree_util.tree_leaves(f0.adapters),
                    jax.tree_util.tree_leaves(f1.adapters)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fleet_mesh_calibration_compressed_within_tolerance():
    """int8 error-feedback reduction: step-0 losses exact (computed
    before any compressed update lands), trajectory bounded, and NOT
    bitwise (compression must actually be in the loop)."""
    from repro.configs import get_arch
    from repro.fleet.fleet import Fleet

    cfg = get_arch("qwen3-1.7b").smoke

    def run(mesh=None, grad_compress=False):
        fleet = Fleet.program(cfg, 0, n_chips=4, backend="dequant")
        fleet.advance(24.0)
        rep = fleet.calibrate(steps=3, mesh=mesh, grad_compress=grad_compress)
        return rep, fleet

    rep0, f0 = run()
    rep2, f2 = run(mesh=_mesh((2, 4)), grad_compress=True)
    np.testing.assert_array_equal(rep0.losses[0], rep2.losses[0])
    d = max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for a, b in zip(jax.tree_util.tree_leaves(f0.adapters),
                        jax.tree_util.tree_leaves(f2.adapters))
    )
    assert 0 < d < 5e-2, d


def test_fleet_mesh_rejects_nondivisible_chip_selection():
    from repro.configs import get_arch
    from repro.fleet.fleet import Fleet

    cfg = get_arch("qwen3-1.7b").smoke
    fleet = Fleet.program(cfg, 0, n_chips=3, backend="dequant")
    with pytest.raises(ValueError, match="divide"):
        fleet.calibrate(steps=1, mesh=_mesh((2, 4)))


def test_elastic_mesh_preserves_model_axis_devices():
    base = _mesh((2, 4))
    from repro.launch.mesh import make_elastic_mesh

    degraded = make_elastic_mesh(1, base_mesh=base)
    assert dict(degraded.shape) == {"data": 1, "model": 4}
    # surviving row keeps the exact device order of the base mesh
    assert list(np.asarray(degraded.devices).ravel()) == list(
        np.asarray(base.devices)[0].ravel()
    )
    with pytest.raises(ValueError, match="capacity"):
        make_elastic_mesh(2, base_mesh=base)
