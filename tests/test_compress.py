"""Error-feedback int8 gradient compression (optim/compress.py).

The EF guarantee this pins: with a CONSTANT gradient g, the compressed
updates telescope — q_t = g + r_{t-1} - r_t — so the running mean of
what ``allreduce_compressed`` emits differs from g by exactly
(r_0 - r_T)/T. A single quantized step is biased (that's what makes the
test meaningful); the bias of the ACCUMULATED trajectory shrinks as 1/T.
Runs on one device: shard_map over a size-1 "data" axis binds the axis
name ``allreduce_compressed`` psums over.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.compress import allreduce_compressed, compress, init_residual


def _one_device_step():
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))

    def body(g, r):
        return allreduce_compressed(g, r, "data")

    return jax.jit(
        shard_map(
            body, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False,
        )
    )


def test_compress_returns_codes_scales_residual():
    g = {"a": jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)}
    r = init_residual(g)
    codes, scales, new_r = compress(g, r)
    assert codes["a"].dtype == jnp.int8
    assert scales["a"].shape == ()
    assert new_r["a"].shape == g["a"].shape
    # dequantized codes + residual reconstruct the input exactly
    recon = codes["a"].astype(jnp.float32) * scales["a"] + new_r["a"]
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g["a"]),
                               rtol=0, atol=1e-6)


def test_error_feedback_shrinks_accumulated_bias():
    step = _one_device_step()
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (16, 16)) * 0.37}
    r = init_residual(g)

    # one quantized step IS biased — otherwise the property is vacuous
    q1, _ = step(g, r)
    e1 = float(np.max(np.abs(np.asarray(q1["w"]) - np.asarray(g["w"]))))
    assert e1 > 0

    biases = []
    acc = jnp.zeros_like(g["w"])
    r = init_residual(g)
    for t in range(1, 33):
        q, r = step(g, r)
        acc = acc + q["w"]
        biases.append(float(np.max(np.abs(np.asarray(acc / t - g["w"])))))
    # telescoping: accumulated bias after T steps = |r_0 - r_T| / T
    assert biases[31] < biases[3] < biases[0]
    # and it tracks the 1/T envelope, not just "eventually smaller"
    assert biases[31] <= biases[7] / 2 + 1e-7


def test_allreduce_mean_is_exact_when_lossless():
    # absmax 127 makes the scale exactly 1.0: integer grads quantize
    # losslessly -> psum mean must be bitwise the input, residual zero
    step = _one_device_step()
    g = {"w": jnp.asarray([[127.0, -64.0], [32.0, 0.0]], jnp.float32)}
    q, r = step(g, init_residual(g))
    np.testing.assert_array_equal(np.asarray(q["w"]), np.asarray(g["w"]))
    np.testing.assert_array_equal(np.asarray(r["w"]), np.zeros((2, 2)))
