"""Calibration registry: artifact round-trip and version monotonicity,
promotion only when the reference has gone unstable, deterministic
nearest-reference lookup, and fleet warm-start parity (a warm-started
chip's loss is no worse than a cold-started one after equal steps)
(ISSUE 8 acceptance)."""
import json

import numpy as np
import pytest

import jax

from repro.checkpoint import CheckpointManager, as_manager
from repro.configs import get_arch
from repro.deploy import Deployment
from repro.deploy.deployment import CalibrationReport
from repro.fleet import Fleet, RecalibrationScheduler
from repro.registry import (
    DEFAULT_THRESHOLDS,
    CalibrationRegistry,
    PromotionPolicy,
    StabilityThresholds,
    drift_signature,
    nearest_reference,
    signature_key,
    stability_metrics,
)


def _cfg():
    return get_arch("qwen3_1_7b").smoke


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb) and len(la) > 0
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def calibrated(tmp_path_factory):
    """One deployment calibrated twice through a registry (24h then 48h
    of drift), shared by the read-only assertions below."""
    root = tmp_path_factory.mktemp("registry")
    reg = CalibrationRegistry(str(root))
    dep = Deployment.program(_cfg(), 0)
    dep.advance(24.0)
    r1 = dep.calibrate(4, steps=4, seq_len=16, registry=reg)
    dep.advance(24.0)
    r2 = dep.calibrate(4, steps=4, seq_len=16, registry=reg)
    return reg, dep, r1, r2


# -- artifact round-trip and version monotonicity ----------------------------


def test_artifact_roundtrip_bitwise(calibrated):
    reg, dep, _, _ = calibrated
    key = reg.key_for(dep.cfg, dep.backend, dep.drift_signature())
    versions = reg.versions(key)
    assert versions, "second calibrate must have recorded an artifact"
    rec = reg.artifact(key, versions[-1])
    like = {"adapters": dep.adapters, "opt": dep.opt_state}
    trees = reg.load(rec, like)
    _leaves_equal(trees["adapters"], dep.adapters)
    _leaves_equal(trees["opt"], dep.opt_state)


def test_versions_monotone_per_key(calibrated):
    reg, dep, _, _ = calibrated
    # same key twice -> versions 1, 2; distinct keys each start at 1.
    sig = dep.drift_signature()
    key = reg.key_for(dep.cfg, dep.backend, sig)
    dep2 = Deployment.program(_cfg(), 0)
    dep2.advance(24.0)
    dep2.advance(24.0)
    r = dep2.calibrate(4, steps=2, seq_len=16, registry=reg)
    assert reg.versions(key) == [1, 2]
    for k in (key, reg.key_for(dep.cfg, dep.backend, sig)):
        assert k.name == key.name  # key derivation is deterministic
    assert r.losses  # the extra run recorded v2 for the same signature


def test_sidecar_metadata(calibrated):
    reg, dep, _, r2 = calibrated
    key = reg.key_for(dep.cfg, dep.backend, dep.drift_signature())
    rec = reg.artifact(key, 1)  # v1 of the 48h key is the fixture's r2
    assert rec.meta["backend"] == dep.backend
    assert rec.meta["report"]["final_loss"] == pytest.approx(r2.final_loss)
    assert "metrics" in rec.meta and "promotion" in rec.meta
    # artifact exists iff its sidecar exists: samples ride along
    assert reg.samples(rec) is not None


# -- promotion policy --------------------------------------------------------


def test_first_run_always_promotes(calibrated):
    reg, dep, _, _ = calibrated
    # dep's FIRST calibrate used the 24h signature -> that key's v1 must
    # be the promoted reference (first run for a key always promotes).
    sig1 = drift_signature(
        dep.cfg.rram, dep.program_key, field_hours=24.0, drift_events=1
    )
    key1 = reg.key_for(dep.cfg, dep.backend, sig1)
    ref = reg.reference(key1)
    assert ref is not None and ref.version == 1 and ref.promoted


def test_promotes_only_when_unstable(tmp_path):
    # Thresholds at infinity: everything is stable, so v2 for the same
    # key must NOT displace v1 as the reference. Thresholds at zero:
    # any drift is instability, so v2 must take over.
    cfg = _cfg()
    for name, thr, want_ref in (
        ("lenient", StabilityThresholds(1e9, 1e9, 1e9, 1e9, 1e9), 1),
        ("strict", StabilityThresholds(0.0, 0.0, 0.0, 0.0, 0.0), 2),
    ):
        reg = CalibrationRegistry(str(tmp_path / name), thresholds=thr)
        dep = Deployment.program(cfg, 0)
        dep.advance(24.0)
        dep.calibrate(4, steps=2, seq_len=16, registry=reg)
        dep.calibrate(4, steps=2, seq_len=16, registry=reg)
        key = reg.key_for(cfg, dep.backend, dep.drift_signature())
        assert reg.versions(key) == [1, 2]
        ref = reg.reference(key)
        assert ref is not None and ref.version == want_ref, name


def test_promotion_policy_reasons():
    policy = PromotionPolicy()
    assert policy.decide(has_reference=False, metrics=None).promote
    assert policy.decide(has_reference=True, metrics=None).promote
    x = np.linspace(-1.0, 1.0, 512)
    stable = stability_metrics(x, x)
    assert stable.is_stable
    assert not policy.decide(has_reference=True, metrics=stable).promote
    shifted = stability_metrics(x + 0.5, x)
    assert not shifted.is_stable
    assert policy.decide(has_reference=True, metrics=shifted).promote


# -- nearest-reference lookup ------------------------------------------------


def test_nearest_reference_deterministic(calibrated):
    reg, dep, _, _ = calibrated
    sig = dep.drift_signature()
    recs = [
        nearest_reference(reg, dep.cfg, dep.backend, sig) for _ in range(3)
    ]
    assert all(r is not None for r in recs)
    assert len({(r.key.name, r.version) for r in recs}) == 1
    # own-history wins: the nearest reference carries dep's own device
    # feature (the promoted 24h key), not some other chip's.
    # stored signatures are quantized to 6 decimals
    assert recs[0].signature[0] == pytest.approx(float(sig[0]), abs=1e-6)


def test_nearest_reference_empty(tmp_path):
    reg = CalibrationRegistry(str(tmp_path))
    dep = Deployment.program(_cfg(), 0)
    assert nearest_reference(
        reg, dep.cfg, dep.backend, dep.drift_signature()
    ) is None
    # warm_start=True against an empty registry falls back to cold
    rep = dep.calibrate(2, steps=1, seq_len=16, warm_start=True,
                        registry=reg, record=False)
    assert rep.warm_started is False and rep.warm_source is None


def test_signature_key_quantization():
    a = np.array([0.1, 0.2, 0.3])
    assert signature_key(a) == signature_key(a + 1e-9)
    assert signature_key(a) != signature_key(a + 1e-3)


# -- warm-start --------------------------------------------------------------


def test_deployment_warmstart_lowers_initial_loss(tmp_path):
    cfg = _cfg()
    reg = CalibrationRegistry(str(tmp_path))
    dep = Deployment.program(cfg, 0)
    dep.advance(24.0)
    dep.calibrate(4, steps=6, seq_len=16, registry=reg)
    dep.advance(24.0)
    dep.reset_adapters()  # model a fresh process: adapters back to zero
    warm = dep.calibrate(
        4, steps=3, seq_len=16, registry=reg, warm_start=True
    )
    cold_dep = Deployment.program(cfg, 0)
    cold_dep.advance(24.0)
    cold_dep.advance(24.0)
    cold = cold_dep.calibrate(4, steps=3, seq_len=16)
    assert warm.warm_started and warm.warm_source
    assert not cold.warm_started
    assert warm.initial_loss < cold.initial_loss
    assert warm.final_loss <= cold.final_loss


def test_fleet_warmstart_parity(tmp_path):
    """A warm-started chip's loss is <= the cold-started chip's after
    the same number of steps (ISSUE 8 acceptance)."""
    cfg = _cfg()
    reg = CalibrationRegistry(str(tmp_path))
    fl = Fleet.program(cfg, 0, n_chips=2)
    fl.advance(24.0)
    fl.calibrate(4, steps=6, seq_len=16, registry=reg)
    fl.advance(24.0)
    fl.reset_adapters()
    warm = fl.calibrate(
        4, steps=3, seq_len=16, registry=reg, warm_start=True
    )
    cold_fl = Fleet.program(cfg, 0, n_chips=2)
    cold_fl.advance(24.0)
    cold_fl.advance(24.0)
    cold = cold_fl.calibrate(4, steps=3, seq_len=16)
    assert warm.warm_started_chips == [0, 1]
    assert len(warm.warm_sources) == 2
    warm_final = np.asarray(warm.losses)[-1]
    cold_final = np.asarray(cold.losses)[-1]
    assert np.all(warm_final <= cold_final)


def test_fleet_virgin_chip_falls_back_to_sibling(tmp_path):
    """A chip with no history of its own seeds from a sibling's
    reference rather than starting cold."""
    cfg = _cfg()
    reg = CalibrationRegistry(str(tmp_path))
    fl = Fleet.program(cfg, 0, n_chips=2)
    fl.advance(24.0)
    # only chip 0 ever calibrates -> the registry holds chip-0 keys only
    fl.calibrate(4, steps=4, seq_len=16, chips=[0], registry=reg)
    fl.advance(24.0)
    fl.reset_adapters()
    warm = fl.calibrate(
        4, steps=1, seq_len=16, chips=[1], registry=reg, warm_start=True
    )
    assert warm.warm_started_chips == [1]
    sig0 = fl.chip_signature(0)
    assert warm.warm_sources[0].startswith(
        reg.key_for(cfg, fl.backend, sig0).cfg_fp
    )


def test_fleet_loss_threshold_early_stop(tmp_path):
    cfg = _cfg()
    fl = Fleet.program(cfg, 0, n_chips=2)
    fl.advance(24.0)
    full = fl.calibrate(4, steps=6, seq_len=16)
    assert full.epochs_run == 6
    fl2 = Fleet.program(cfg, 0, n_chips=2)
    fl2.advance(24.0)
    thr = float(np.max(np.asarray(full.losses)[0])) + 1.0  # above epoch 1
    early = fl2.calibrate(4, steps=6, seq_len=16, loss_threshold=thr)
    assert early.epochs_run < 6


def test_scheduler_reports_epoch_savings(tmp_path):
    cfg = _cfg()
    reg = CalibrationRegistry(str(tmp_path))
    fl = Fleet.program(cfg, 0, n_chips=2)
    sched = RecalibrationScheduler(
        fl, threshold=1e-4,
        calib_args=dict(
            batch_or_samples=4, steps=6, seq_len=16, loss_threshold=0.04
        ),
        registry=reg,
    )
    rep = sched.run([24.0, 24.0])
    assert rep.warm_started_recalibrations > 0
    assert rep.calibration_chip_epoch_budget >= rep.calibration_chip_epochs
    assert rep.calibration_epochs_saved == (
        rep.calibration_chip_epoch_budget - rep.calibration_chip_epochs
    )
    json.loads(rep.to_json())


# -- satellites: report JSON, as_manager -------------------------------------


def test_calibration_report_json_roundtrip():
    rep = CalibrationReport(
        losses=[0.5, 0.25], epochs_run=2, sram_bytes=64, rram_bytes=256,
        base_params=1024, adapter_params=24, calibrated_fraction=0.0234,
        backend="dequant", drift_events=3,
        warm_started=True, warm_source="abc/dequant/def@v2",
    )
    assert rep.initial_loss == pytest.approx(0.5)
    assert rep.final_loss == pytest.approx(0.25)
    back = CalibrationReport.from_json(rep.to_json())
    assert back.to_dict() == rep.to_dict()
    assert back == rep


def test_as_manager_coercion(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    assert as_manager(mgr) is mgr
    made = as_manager(tmp_path / "sub")
    assert isinstance(made, CheckpointManager)
    made.save(1, {"x": np.arange(3)})
    out = made.restore(1, {"x": np.zeros(3)})
    np.testing.assert_array_equal(out["x"], np.arange(3))
