"""Deployment lifecycle API: legacy-shim parity, drift clock,
snapshot/restore, and the multi-drift-epoch scenario the one-shot API
could not represent (ISSUE 3 acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import deploy
from repro.configs import get_arch
from repro.core import calibrate as C
from repro.core import rram
from repro.deploy import Deployment
from repro.launch import serve, train


def _cfg():
    return get_arch("qwen3_1_7b").smoke


def _batch(cfg, b=2, s=16, seed=0):
    return {"tokens": jax.random.randint(
        jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab
    )}


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(
        a, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
    lb = jax.tree_util.tree_leaves(
        b, is_leaf=lambda n: isinstance(n, rram.CrossbarWeight)
    )
    assert len(la) == len(lb) and len(la) > 0
    for x, y in zip(la, lb):
        if isinstance(x, rram.CrossbarWeight):
            assert isinstance(y, rram.CrossbarWeight)
            np.testing.assert_array_equal(np.asarray(x.g_pos), np.asarray(y.g_pos))
            np.testing.assert_array_equal(np.asarray(x.g_neg), np.asarray(y.g_neg))
            np.testing.assert_array_equal(np.asarray(x.scale), np.asarray(y.scale))
        else:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -- legacy shim parity ------------------------------------------------------


@pytest.mark.parametrize("backend,mode", [("dequant", "dequant"), ("codes", "codes")])
def test_deployment_parity_with_legacy_free_functions(backend, mode):
    """program_model + merge_adapters_for_serve + backend scoping (the
    legacy wiring) vs Deployment: bitwise-identical resident base and
    identical logits for the same seed/arch/backend."""
    cfg = _cfg()
    seed = 0
    # legacy wiring, exactly as launch/serve.py used to hand-build it
    from repro.models import transformer as T
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    legacy_base = C.program_model(
        params["base"], cfg.rram, jax.random.PRNGKey(seed + 1), mode=mode
    )
    legacy = {
        "base": legacy_base,
        "adapters": C.merge_adapters_for_serve(legacy_base, params["adapters"]),
    }
    dep = Deployment.program(cfg, seed, backend=backend)
    session = dep.serve()
    # the deployment's resident base and merged adapters are bitwise the
    # legacy wiring's; under codes the SESSION additionally carries the
    # prepared (padded/fused) serving tree, so compare the source trees
    _assert_trees_equal(legacy["base"], dep.base)
    _assert_trees_equal(legacy["adapters"], session.params["adapters"])

    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab)
    with deploy.backend_scope(backend, cfg):
        logits_legacy, _ = deploy.prefill_and_cache(legacy, prompt, cfg, 8)
    logits_dep, _ = session.prefill(prompt, 8)
    np.testing.assert_array_equal(
        np.asarray(logits_legacy), np.asarray(logits_dep)
    )


def test_load_student_shim_matches_deployment_serve():
    cfg = _cfg()
    shim = serve.load_student(cfg, seed=3, backend="codes")
    dep = Deployment.program(cfg, 3, backend="codes")
    session = dep.serve()
    # the shim keeps the legacy raw layout; the session's serving tree is
    # prepared (padded/fused) but derives from the same base + adapters
    _assert_trees_equal(shim["base"], dep.base)
    _assert_trees_equal(shim["adapters"], session.params["adapters"])
    # and both serve identical logits for the same prompt
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, 4), 0, cfg.vocab)
    with deploy.backend_scope("codes", cfg):
        logits_shim, _ = deploy.prefill_and_cache(shim, prompt, cfg, 6)
    logits_dep, _ = session.prefill(prompt, 6)
    np.testing.assert_array_equal(
        np.asarray(logits_shim), np.asarray(logits_dep)
    )


def test_build_state_shim_matches_deployment_calib_state():
    cfg = _cfg()
    state = train.build_state(cfg, seed=1)
    dep_state = Deployment.program(cfg, 1).calib_state()
    _assert_trees_equal(state.student_base, dep_state.student_base)
    _assert_trees_equal(state.adapters, dep_state.adapters)


# -- drift clock -------------------------------------------------------------


def test_advance_deterministic_per_event_index():
    cfg = _cfg()
    d1 = Deployment.program(cfg, 0, backend="codes").advance(24.0)
    d2 = Deployment.program(cfg, 0, backend="codes").advance(24.0)
    _assert_trees_equal(d1.codes, d2.codes)
    # a second tick of the SAME duration draws fresh noise (event index
    # is folded into the key) and compounds on the first
    before = jax.tree_util.tree_map(
        lambda x: np.asarray(x),
        d1.codes["body"][0]["mixer"]["q"]["w"].g_pos,
    )
    d1.advance(24.0)
    after = np.asarray(d1.codes["body"][0]["mixer"]["q"]["w"].g_pos)
    assert not np.array_equal(before, after)
    assert d1.drift_hours == [24.0, 24.0]


def test_advance_degrades_agreement_monotonically():
    cfg = _cfg()
    dep = Deployment.program(cfg, 0)
    batch = _batch(cfg)
    gap0 = dep.logit_mse(batch, use_adapters=False)
    dep.advance(24.0)
    gap1 = dep.logit_mse(batch, use_adapters=False)
    dep.advance(168.0)
    gap2 = dep.logit_mse(batch, use_adapters=False)
    assert gap0 < gap1 < gap2


def test_advance_zero_hours_is_noop_and_negative_raises():
    """hours=0 is a TRUE no-op — no drift event is recorded (it used to
    append a zero-hour event that consumed an event index, shifting the
    keys of every later tick); negative hours are rejected instead of
    passing silently through the drift model."""
    cfg = _cfg()
    dep = Deployment.program(cfg, 0, backend="codes")
    ref = jax.tree_util.tree_map(
        lambda x: x, dep.codes,
        is_leaf=lambda n: isinstance(n, rram.CrossbarWeight),
    )
    dep.advance(0.0)
    _assert_trees_equal(ref, dep.codes)
    assert dep.drift_hours == []  # no event recorded
    with pytest.raises(ValueError):
        dep.advance(-1.0)
    assert dep.drift_hours == []
    # a zero tick between real ticks does not perturb the event stream:
    # [24] and [0, 24, 0] replay to the same codes
    d1 = Deployment.program(cfg, 0, backend="codes").advance(24.0)
    d2 = Deployment.program(cfg, 0, backend="codes")
    d2.advance(0.0); d2.advance(24.0); d2.advance(0.0)
    _assert_trees_equal(d1.codes, d2.codes)
    assert d1.drift_hours == d2.drift_hours == [24.0]


def test_drift_sigma_log_time():
    cfg = rram.RramConfig(relative_drift=0.1)
    assert rram.drift_sigma(cfg, 0.0) == 0.0
    s24 = rram.drift_sigma(cfg, 24.0)
    s168 = rram.drift_sigma(cfg, 168.0)
    assert 0 < s24 < s168 < 0.1 * np.log1p(168 / 24.0) + 1e-9
    with pytest.raises(ValueError):
        rram.drift_sigma(cfg, -1.0)


def test_drift_sigma_increments_compose():
    """Slicing the same field time into ticks accumulates the same total
    drift variance: sum of increment variances == total variance, so one
    advance(24) and 24x advance(1) model the same 24 field-hours."""
    cfg = rram.RramConfig(relative_drift=0.1)
    total = rram.drift_sigma(cfg, 24.0)
    acc, t = 0.0, 0.0
    for _ in range(24):
        inc = rram.drift_sigma_increment(cfg, t, 1.0)
        acc += inc * inc
        t += 1.0
    assert np.isclose(np.sqrt(acc), total)
    assert np.isclose(rram.drift_sigma_increment(cfg, 0.0, 24.0), total)
    assert rram.drift_sigma_increment(cfg, 24.0, 0.0) == 0.0


def test_drift_model_rejects_float_trees():
    cfg = _cfg()
    dep = Deployment.program(cfg, 0)  # dequant backend: base is floats
    with pytest.raises(ValueError):
        C.drift_model(
            dep.base, cfg.rram, dep.program_key, hours=1.0, event_index=0
        )


# -- snapshot / restore ------------------------------------------------------


def test_snapshot_restore_reproduces_post_drift_post_calib_state(tmp_path):
    cfg = _cfg()
    dep = Deployment.program(cfg, 0, backend="codes")
    dep.advance(24.0)
    batch = _batch(cfg, b=2, s=16)
    dep.calibrate(batch, steps=4, lr=2e-3)
    dep.advance(12.0)
    step = dep.snapshot(str(tmp_path))

    restored = Deployment.restore(cfg, str(tmp_path))
    assert restored.backend == "codes"
    assert restored.step == step
    assert restored.drift_hours == dep.drift_hours
    _assert_trees_equal(dep.codes, restored.codes)
    _assert_trees_equal(dep.adapters, restored.adapters)
    _assert_trees_equal(dep.opt_state, restored.opt_state)
    # the served artifact is identical
    prompt = jax.random.randint(jax.random.PRNGKey(5), (2, 4), 0, cfg.vocab)
    l1, _ = dep.serve().prefill(prompt, 6)
    l2, _ = restored.serve().prefill(prompt, 6)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_restore_replays_legacy_zero_hour_events(tmp_path):
    """Snapshots written before advance(0) became a no-op can contain
    recorded zero-hour events that consumed an event index; restore must
    replay that index consumption (not skip it) so later ticks draw the
    same per-event keys."""
    cfg = _cfg()
    dep = Deployment.program(cfg, 0, backend="codes")
    # simulate the legacy state: a zero-hour event on the record, codes
    # untouched (exactly what the old advance(0.0) did), then a real tick
    # drawing under event_index 1
    dep.drift_hours.append(0.0)
    dep.advance(24.0)
    dep.snapshot(str(tmp_path))
    restored = Deployment.restore(cfg, str(tmp_path))
    assert restored.drift_hours == [0.0, 24.0]
    _assert_trees_equal(dep.codes, restored.codes)


def test_restore_backend_override(tmp_path):
    cfg = _cfg()
    dep = Deployment.program(cfg, 0, backend="dequant")
    dep.advance(24.0)
    dep.snapshot(str(tmp_path))
    restored = Deployment.restore(cfg, str(tmp_path), backend="codes")
    assert restored.backend == "codes"
    # same programming event either way: the codes match bitwise
    _assert_trees_equal(dep.codes, restored.codes)


# -- the multi-drift-epoch scenario (acceptance) -----------------------------


def test_two_drift_epoch_lifecycle():
    """program -> advance -> calibrate -> advance -> recalibrate -> serve:
    feature MSE is restored after EACH calibration, which the one-shot
    free-function API structurally could not express."""
    cfg = _cfg()
    dep = Deployment.program(cfg, 0)
    batch = _batch(cfg, b=4, s=16)

    dep.advance(24.0)
    r1 = dep.calibrate(batch, steps=12, lr=3e-3)
    assert r1.final_loss < r1.initial_loss  # calibration restored accuracy
    assert r1.drift_events == 1

    dep.advance(168.0)
    r2 = dep.calibrate(batch, steps=12, lr=3e-3)
    assert r2.initial_loss > r1.final_loss  # drift degraded it again
    assert r2.final_loss < r2.initial_loss  # ...and was restored again
    assert r2.drift_events == 2

    session = dep.serve()
    toks, _ = session.generate(batch["tokens"][:2, :4], gen_len=3)
    assert toks.shape == (2, 3)
    # report carries the SRAM/fraction accounting
    assert r2.sram_bytes == dep.sram_bytes() > 0
    assert 0 < r2.calibrated_fraction < 1


def test_calibration_report_fields():
    cfg = _cfg()
    dep = Deployment.program(cfg, 0)
    report = dep.calibrate(2, steps=3, seq_len=8)
    assert report.epochs_run == len(report.losses) == 3
    assert report.sram_bytes == C.sram_bytes(dep.adapters)
    assert report.rram_bytes == C.rram_bytes(dep.base)
    assert report.adapter_params > 0 and report.base_params > 0
    assert report.backend == "dequant"
    assert "sram_bytes" in report.summary()


# -- serving fixes -----------------------------------------------------------


def test_generate_samples_first_token():
    """temperature > 0 must sample EVERY generated token, including the
    first (it used to be argmax'd regardless)."""
    cfg = _cfg()
    session = Deployment.program(cfg, 0).serve()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab)
    t1, _ = session.generate(
        prompt, gen_len=1, temperature=8.0, key=jax.random.PRNGKey(10)
    )
    t2, _ = session.generate(
        prompt, gen_len=1, temperature=8.0, key=jax.random.PRNGKey(11)
    )
    # near-uniform sampling over the vocab: different keys give a
    # different first token (argmax would be identical every time)
    assert not np.array_equal(t1, t2)
    # greedy path stays deterministic
    g1, _ = session.generate(prompt, gen_len=1, temperature=0.0)
    g2, _ = session.generate(prompt, gen_len=1, temperature=0.0)
    np.testing.assert_array_equal(g1, g2)


def test_sram_bytes_measures_adapter_arrays():
    cfg = _cfg()
    dep = Deployment.program(cfg, 0)
    expected = sum(
        int(x.nbytes) for x in jax.tree_util.tree_leaves(dep.adapters)
    )
    assert C.sram_bytes(dep.adapters) == expected > 0
    assert 0 < C.calibrated_fraction(dep.base, dep.adapters) < 1
