"""Distribution layer: sharding rules + a real multi-device dry-run cell
(subprocess so XLA_FLAGS device-count forcing doesn't leak into this
process, which must keep seeing 1 CPU device)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_this_process_sees_one_device():
    assert len(jax.devices()) == 1


def test_rules_divisibility_guard():
    """Specs never request sharding a dim that the mesh axis doesn't divide."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.sharding import rules as sh

mesh = jax.make_mesh((2, 4), ("data", "model"))
tree = {
    "mixer": {"q": {"w": jax.ShapeDtypeStruct((16, 10), jnp.float32)}},  # 10 % 4 != 0
    "ffn": {"up": {"w": jax.ShapeDtypeStruct((16, 32), jnp.float32)}},
}
shd = sh.param_shardings(tree, mesh, dp=("data",), tp="model")
assert shd["mixer"]["q"]["w"].spec == P(None, None), shd["mixer"]["q"]["w"].spec
assert shd["ffn"]["up"]["w"].spec == P(None, "model")
print("RULES_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC},
    )
    assert "RULES_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_dryrun_smoke_cell_compiles_multidevice():
    """End-to-end dry-run of a smoke config on a (2,2,2) pod-data-model
    mesh with 8 fake devices: lower + compile + cost/memory analysis."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.configs.shapes import ShapeSpec
from repro.launch import dryrun
from repro.launch import mesh as mesh_lib

# monkeypatch a small production mesh
mesh_lib.make_production_mesh = lambda multi_pod=False: (
    jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
    else jax.make_mesh((2, 4), ("data", "model"))
)
arch = get_arch("qwen3_1_7b")
small = ShapeSpec("train_small", "train", 64, 8)
arch.shapes["train_small"] = small
rl, msg = dryrun.run_cell("qwen3_1_7b", "train_small", multi_pod=False, smoke=True)
assert rl is not None and rl.flops > 0 and rl.coll_bytes >= 0
print("SINGLE_OK", msg)
arch.shapes["dec_small"] = ShapeSpec("dec_small", "decode", 64, 8)
rl2, msg2 = dryrun.run_cell("qwen3_1_7b", "dec_small", multi_pod=True, smoke=True)
print("MULTI_OK", msg2)
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC}, timeout=600,
    )
    assert "SINGLE_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
    assert "MULTI_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]


def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar = f32[128]{0} all-reduce(%y), to_apply=%sum
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b)
  %nothing = f32[4]{0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 512 * 2
    assert out["all-reduce"] == 128 * 4
    assert out["reduce-scatter"] == 2 * 64 * 4
    assert out["_counts"]["all-gather"] == 1


def test_roofline_terms_and_bottleneck():
    from repro.launch.roofline import Roofline, PEAK_FLOPS
    rl = Roofline(
        arch="a", shape="s", mesh="m",
        flops=PEAK_FLOPS, bytes_accessed=0.0, coll_bytes=0.0,
        coll_breakdown={}, peak_memory=1, model_flops=PEAK_FLOPS / 2,
    )
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.bottleneck == "compute"
    assert rl.roofline_fraction == pytest.approx(0.5)
    assert rl.useful_flop_ratio == pytest.approx(0.5)
