"""Fault-tolerance runtime primitives (runtime/fault.py): the straggler
policy acts on PERSISTENT outliers (a one-off spike never triggers a
re-mesh), preemption is a flag flip, and the elastic plan only ever
shrinks the data axis — the model axis (and with it every param
sharding) survives degradation unchanged."""
import numpy as np
import pytest

from repro.runtime.fault import (
    ElasticPlan, PreemptionGuard, StragglerDetector, StepTimer,
)


def _feed_baseline(det, n, t=0.10, start=0):
    for i in range(n):
        det.record(start + i, t + 1e-4 * (i % 3))  # tiny jitter, no outliers
    return start + n


class TestStragglerDetector:
    def test_one_off_spike_is_not_persistent(self):
        det = StragglerDetector(window=64, min_samples=16)
        step = _feed_baseline(det, 32)
        r = det.record(step, 1.5)  # single 15x spike
        assert r is not None and r.is_straggler
        # flagged once, but the policy signal stays down
        assert not det.persistent(k=3, horizon=8)

    def test_persistent_outlier_trips_policy(self):
        det = StragglerDetector(window=64, min_samples=16)
        step = _feed_baseline(det, 32)
        for i in range(3):  # thermally-throttled host: every step slow
            det.record(step + i, 1.5)
        assert det.persistent(k=3, horizon=8)

    def test_no_reports_before_min_samples(self):
        det = StragglerDetector(window=64, min_samples=16)
        for i in range(15):
            assert det.record(i, 10.0 if i % 2 else 0.1) is None
        assert det.reports == []
        assert not det.persistent(k=1, horizon=100)

    def test_recovery_clears_persistence(self):
        det = StragglerDetector(window=64, min_samples=16)
        step = _feed_baseline(det, 32)
        for i in range(4):
            det.record(step + i, 1.5)
        assert det.persistent(k=3, horizon=8)
        _feed_baseline(det, 8, start=step + 4)  # host healthy again
        assert not det.persistent(k=3, horizon=8)


class TestPreemptionGuard:
    def test_request_stop_flips_flag(self):
        with PreemptionGuard() as guard:
            assert not guard.should_stop
            guard.request_stop()
            assert guard.should_stop

    def test_fresh_guard_starts_clear(self):
        with PreemptionGuard() as guard:
            assert not guard.should_stop


class TestElasticPlan:
    def test_model_axis_unchanged(self):
        plan = ElasticPlan.plan(3, 120, rows=16, cols=16)
        assert plan.new_mesh_shape == (13, 16)  # cols untouched
        assert plan.failed_hosts == 3
        assert plan.restore_step == 120

    def test_serve_mesh_shapes(self):
        plan = ElasticPlan.plan(1, 7, rows=2, cols=4)
        assert plan.new_mesh_shape == (1, 4)

    def test_no_capacity_raises(self):
        with pytest.raises(RuntimeError):
            ElasticPlan.plan(16, 0, rows=16, cols=16)

    def test_none_step_restores_at_zero(self):
        assert ElasticPlan.plan(1, None).restore_step == 0


def test_step_timer_measures_elapsed():
    with StepTimer() as t:
        x = sum(range(1000))
    assert t.elapsed >= 0.0 and x == 499500
