"""Per-architecture smoke tests (deliverable f) + decode consistency.

Every assigned arch instantiates its REDUCED config, runs one forward and
one calibration train step on CPU, and asserts output shapes + no NaNs.
Decode-vs-forward consistency is checked on a representative subset of
families (dense/qk_norm, SSM, hybrid, MLA+MoE, SWA).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.core import calibrate as C
from repro.core.calibrate import CalibState, make_calib_step
from repro.models import transformer as T
from repro.optim.adam import AdamW, adamw_init

B, S = 2, 16


def _batch(cfg, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(
            jax.random.fold_in(k, 1), (B, S, cfg.d_model), jnp.bfloat16
        )
    if cfg.vision_tokens:
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(k, 2), (B, cfg.vision_tokens, cfg.d_model),
            jnp.bfloat16,
        )
    return batch


@pytest.fixture(scope="module")
def smoke_runs():
    return {}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_shapes_and_finiteness(arch_id):
    cfg = get_arch(arch_id).smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = T.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_calibration_step(arch_id):
    """One full calibration train step: loss finite and adapters update."""
    cfg = get_arch(arch_id).smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    student = C.program_model(params["base"], cfg.rram, jax.random.PRNGKey(1))
    state = CalibState(
        params["base"], student, params["adapters"],
        adamw_init(params["adapters"]), jnp.zeros((), jnp.int32),
    )
    step = make_calib_step(cfg, AdamW(lr=1e-3))
    new_state, metrics = jax.jit(step)(state, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # adapters changed
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).sum()), state.adapters,
        new_state.adapters,
    )
    assert sum(jax.tree_util.tree_leaves(diff)) > 0


@pytest.mark.parametrize(
    "arch_id",
    ["qwen3_1_7b", "falcon_mamba_7b", "recurrentgemma_9b",
     "deepseek_v2_lite_16b", "mixtral_8x22b"],
)
def test_decode_matches_forward(arch_id):
    """Step-by-step decode logits == full-sequence forward logits (teacher
    weights, no drift) — validates every cache implementation."""
    cfg = get_arch(arch_id).smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    full = T.forward(params, batch, cfg, use_adapters=False)
    cache = T.init_cache(cfg, B, S)
    p = {"base": params["base"], "adapters": T._empty_adapters(params["adapters"])}
    outs = []
    for i in range(S):
        logits, cache = T.decode_step(
            p, cache, batch["tokens"][:, i : i + 1], jnp.int32(i), cfg
        )
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full, np.float32), rtol=0.15, atol=0.15
    )


def test_decode_matches_forward_encdec():
    cfg = get_arch("seamless_m4t_large_v2").smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    full = T.forward(params, batch, cfg, use_adapters=False)
    cache = T.init_cache(cfg, B, S, src_len=S)
    adapters = T._empty_adapters(params["adapters"])
    p = {"base": params["base"], "adapters": adapters}
    cache = T.encode_into_cache(p, cache, batch["enc_embeds"], cfg)
    outs = []
    for i in range(S):
        logits, cache = T.decode_step(
            p, cache, batch["tokens"][:, i : i + 1], jnp.int32(i), cfg
        )
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full, np.float32), rtol=0.15, atol=0.15
    )


def test_sliding_window_cache_is_rolling():
    """With seq > window, decode must keep working (rolling buffer) and the
    cache allocation stays at the window size."""
    cfg = get_arch("mixtral_8x22b").smoke  # window 16
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n = cfg.local_window + 8
    cache = T.init_cache(cfg, B, n)
    # body cache is stacked (G, B, L, kvh, hd): L (dim 2) == window
    assert cache["body"][0]["k"].shape[2] == cfg.local_window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, n), 0, cfg.vocab)
    p = {"base": params["base"], "adapters": T._empty_adapters(params["adapters"])}
    for i in range(n):
        logits, cache = T.decode_step(
            p, cache, toks[:, i : i + 1], jnp.int32(i), cfg
        )
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_count_params_and_adapter_fraction():
    cfg = get_arch("qwen3_1_7b").smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    nb, na = T.count_params(params)
    assert nb > 0 and na > 0
    assert na / nb < 0.35  # smoke configs are tiny; fraction is larger than full


def test_calibration_loss_is_layer_local():
    """Gradient w.r.t. layer-l adapters of the summed loss equals the
    gradient of ONLY layer l's MSE — Algorithm 1's locality (DESIGN.md §2)."""
    cfg = get_arch("qwen3_1_7b").smoke
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    student = C.program_model(params["base"], cfg.rram, jax.random.PRNGKey(1))
    batch = _batch(cfg)

    def full_loss(ad):
        return T.feature_calibration_loss(
            params["base"], student, ad, batch, cfg
        )[0]

    g = jax.grad(full_loss)(params["adapters"])
    # perturb layer 0's adapter (stacked scan body, leading layer axis):
    # the gradient for layer 1's adapters must be unchanged (no cross-layer
    # gradient flow)
    ad2 = jax.tree_util.tree_map(lambda x: x, params["adapters"])
    la = ad2["body"][0]["mixer"]["q"]["lora_a"]
    ad2["body"][0]["mixer"]["q"]["lora_a"] = la.at[0].add(0.05)
    g2 = jax.grad(full_loss)(ad2)
    a = np.asarray(g["body"][0]["mixer"]["q"]["lora_a"])[1:]
    b = np.asarray(g2["body"][0]["mixer"]["q"]["lora_a"])[1:]
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
