"""Integration: training driver (checkpoint/restart/preemption) and the
serving driver, at smoke scale."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve, train


def test_train_loss_decreases_and_checkpoints(tmp_path):
    out = train.train(
        "qwen3-1.7b", smoke=True, steps=12, batch=4, seq=16, lr=2e-3,
        ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100,
    )
    assert out["final_loss"] is not None
    h = out["history"]
    assert np.mean(h[-3:]) < h[0]  # robust to single-step optimizer noise
    from repro.checkpoint.manager import CheckpointManager
    assert CheckpointManager(str(tmp_path)).all_steps()  # saved something


def test_train_restart_resumes(tmp_path):
    train.train(
        "qwen3-1.7b", smoke=True, steps=4, batch=2, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100,
    )
    out = train.train(
        "qwen3-1.7b", smoke=True, steps=6, batch=2, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100,
    )
    # resumed from step 4: only 2 new steps in history
    assert len(out["history"]) == 2


def test_calibration_improves_student_teacher_agreement():
    """End-to-end paper mechanism on the LM stack: after calibration the
    student's logits match the teacher better than before."""
    from repro.configs import get_arch
    from repro.models import transformer as T

    arch = get_arch("qwen3-1.7b")
    cfg = arch.smoke
    out = train.train(
        "qwen3-1.7b", smoke=True, steps=25, batch=4, seq=32, lr=2e-3,
        log_every=100,
    )
    state = out["state"]
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(9), (4, 32), 0, cfg.vocab)
    }
    t_logits = T.forward(
        {"base": state.teacher_base, "adapters": {}}, batch, cfg,
        use_adapters=False,
    ).astype(jnp.float32)
    s_before = T.forward(
        {"base": state.student_base, "adapters": state.adapters}, batch, cfg,
        use_adapters=False,  # student WITHOUT adapters
    ).astype(jnp.float32)
    s_after = T.forward(
        {"base": state.student_base, "adapters": state.adapters}, batch, cfg,
        use_adapters=True,
    ).astype(jnp.float32)
    err_before = float(jnp.mean((t_logits - s_before) ** 2))
    err_after = float(jnp.mean((t_logits - s_after) ** 2))
    assert err_after < err_before


def test_serve_generates(tmp_path):
    from repro.configs import get_arch
    cfg = get_arch("qwen3-1.7b").smoke
    params = serve.load_student(cfg, seed=0)
    prompt = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0, cfg.vocab)
    toks, dt = serve.generate(params, prompt, cfg, gen_len=4)
    assert toks.shape == (2, 4)
    assert toks.dtype == np.int32 or toks.dtype == np.int64


def test_serve_encdec_generates():
    from repro.configs import get_arch
    cfg = get_arch("seamless-m4t-large-v2").smoke
    params = serve.load_student(cfg, seed=0)
    prompt = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0, cfg.vocab)
    enc = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.bfloat16)
    toks, _ = serve.generate(params, prompt, cfg, gen_len=3, enc_embeds=enc)
    assert toks.shape == (2, 3)
