"""End-to-end driver: calibration-train a ~100M-param qwen3-family model
for a few hundred steps with checkpointing + preemption safety — the
framework's production loop at CPU-runnable scale.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.configs.shapes import ArchSpec
from repro.launch import train as train_lib
from repro.models.attention import AttentionConfig
from repro.models.layers import MlpConfig


def hundred_m_config():
    """~100M-parameter member of the qwen3 family."""
    base = get_arch("qwen3-1.7b").full
    return dataclasses.replace(
        base,
        name="qwen3-100m",
        d_model=512,
        n_layers=8,
        vocab=32000,
        attn=AttentionConfig(
            d_model=512, num_heads=8, num_kv_heads=4, head_dim=64, qk_norm=True
        ),
        mlp=MlpConfig(d_model=512, d_ff=1536, gated=True, activation="silu"),
        remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    # register the custom config as a one-off arch
    import repro.configs as configs
    cfg = hundred_m_config()
    spec = ArchSpec(name="qwen3-100m", full=cfg, smoke=cfg, shapes={}, skips={})
    configs.ARCH_IDS.append("qwen3_100m")
    import sys, types
    mod = types.ModuleType("repro.configs.qwen3_100m")
    mod.ARCH = spec
    sys.modules["repro.configs.qwen3_100m"] = mod

    # the driver constructs its deployment through repro.deploy and hands
    # it back; snapshots in ckpt_dir are Deployment.restore-compatible
    out = train_lib.train(
        "qwen3_100m", smoke=False, steps=args.steps, batch=2, seq=128,
        lr=1e-3, ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10,
    )
    print(f"final calibration loss: {out['final_loss']:.6f} "
          f"(from {out['history'][0]:.6f})")
    dep = out["deployment"]
    print(f"calibrated deployment: sram_bytes={dep.sram_bytes()} "
          f"({dep.calibrated_fraction():.2%} of params), "
          f"rram_bytes={dep.rram_bytes()}")


if __name__ == "__main__":
    main()
