"""Distributed serving & fleet calibration example: the mesh-native
lifecycle on a forced multi-device CPU host.

One deployment, three mesh moments:

1. **Tensor-parallel serving** — ``Deployment.serve(mesh=...)`` shards
   the prepared codes tree column-wise over the mesh's "model" axis
   (sharding/rules.py decides which leaves; the rest replicate) and runs
   every decode tick as one ``shard_map`` with a psum epilogue. Output
   is BITWISE the single-device session's.
2. **Elastic degradation** — ``ServeEngine.remesh()`` drops a data-axis
   host mid-serve and replays every in-flight slot (prompt + emitted
   tokens at their original positions) onto the surviving devices;
   streams continue exactly where they left off.
3. **Mesh fleet calibration** — ``Fleet.calibrate(mesh=...)`` shards
   the chip axis over "data" (bitwise vs single-device), and
   ``grad_compress=True`` routes adapter gradients through the int8
   error-feedback collective.

Run:  PYTHONPATH=src python examples/mesh_serve.py

The XLA device-count forcing below must happen before jax is imported —
running this inside a process that already initialised jax with one CPU
device will fail the device-count check.
"""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
)

import jax  # noqa: E402
import numpy as np  # noqa: E402


def main():
    from repro.configs import get_arch
    from repro.deploy import Deployment, ServeEngine
    from repro.fleet.fleet import Fleet
    from repro.launch.mesh import make_host_mesh

    assert jax.device_count() >= 8, (
        f"saw {jax.device_count()} devices — XLA_FLAGS forcing didn't take"
    )
    cfg = get_arch("qwen3-1.7b").smoke

    # -- 1. tensor-parallel serving (codes backend holds the RRAM codes) --
    dep = Deployment.program(cfg, key=0, backend="codes")
    dep.advance(hours=24)
    dep.calibrate(4, steps=10, lr=3e-3, seq_len=32)

    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    )
    single = dep.serve()
    ref, _ = single.generate(prompt, gen_len=6)

    tp = dep.serve(mesh=make_host_mesh((1, 4)))
    print("wrap policy:", tp.shard_stats)
    got, _ = tp.generate(prompt, gen_len=6)
    assert np.array_equal(np.asarray(ref), np.asarray(got))
    print("tensor-parallel generation bitwise-matches single-device\n")

    # -- 2. elastic degradation mid-serve ---------------------------------
    engine = ServeEngine(dep.serve(mesh=make_host_mesh((2, 4))),
                         max_slots=2, max_len=48)
    reqs = [engine.submit(np.arange(5) % cfg.vocab, max_new=10),
            engine.submit((np.arange(9) * 7) % cfg.vocab, max_new=10)]
    for _ in range(3):
        engine.step()
    plan = engine.remesh()  # a host just died
    print(f"re-mesh: {plan.failed_hosts} host lost -> "
          f"{plan.new_mesh_shape}; {plan.notes}")
    engine.run()
    print("streams after recovery:", [r.tokens for r in reqs], "\n")

    # -- 3. fleet calibration over the data axis --------------------------
    fleet = Fleet.program(cfg, 0, n_chips=4, backend="dequant")
    fleet.advance(24.0)
    report = fleet.calibrate(
        steps=5, mesh=make_host_mesh((2, 4)), grad_compress=True
    )
    print(report.summary())


if __name__ == "__main__":
    main()
