"""Batched serving example: calibrate, merge DoRA magnitudes, then serve
batched requests with prefill + decode against the KV cache.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch import serve, train


def main():
    arch = get_arch("qwen3-1.7b")
    cfg = arch.smoke
    # quick calibration so the served model is the paper's artifact
    out = train.train("qwen3-1.7b", smoke=True, steps=15, batch=4, seq=32,
                      lr=3e-3, log_every=5)
    state = out["state"]
    params = {"base": state.student_base, "adapters": state.adapters}

    key = jax.random.PRNGKey(0)
    # 8 concurrent requests, batch-decoded
    prompts = jax.random.randint(key, (8, 12), 0, cfg.vocab)
    toks, dt = serve.generate(params, prompts, cfg, gen_len=16,
                              temperature=0.8, key=key)
    print(f"served 8 requests x 16 tokens in {dt:.2f}s "
          f"({8 * 16 / dt:.1f} tok/s on 1 CPU core)")
    print("first two continuations:", toks[:2].tolist())


if __name__ == "__main__":
    main()
