"""Batched serving example: program a deployment, calibrate it, then
serve batched requests (prefill + decode against the KV cache) with
temperature sampling — every stage through ``repro.deploy.Deployment``.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import jax

from repro.configs import get_arch
from repro.deploy import Deployment


def main():
    cfg = get_arch("qwen3-1.7b").smoke
    # program + quick calibration so the served model is the paper's artifact
    dep = Deployment.program(cfg, key=0)
    dep.advance(hours=24)
    report = dep.calibrate(4, steps=15, lr=3e-3, seq_len=32)
    print(report.summary())

    session = dep.serve()
    print(session.describe())
    key = jax.random.PRNGKey(0)
    # 8 concurrent requests, batch-decoded; temperature sampling applies
    # from the FIRST generated token
    prompts = jax.random.randint(key, (8, 12), 0, cfg.vocab)
    toks, dt = session.generate(prompts, gen_len=16, temperature=0.8, key=key)
    print(f"served 8 requests x 16 tokens in {dt:.2f}s "
          f"({8 * 16 / dt:.1f} tok/s on 1 CPU core)")
    print("first two continuations:", toks[:2].tolist())


if __name__ == "__main__":
    main()
