"""Continuous-batching serving example: program a deployment, calibrate
it, then serve ragged concurrent requests through ``ServeEngine`` —
slot-based scheduling over one fixed (max_slots, max_len) cache, chunked
prefill at admission, one compiled batched decode step for every tick.

The second half demos the shared prefix cache: every request opens with
the same system prompt, so after the first admission the engine resumes
each later request from a chunk-boundary snapshot instead of re-running
the shared tokens — same tokens bitwise, measurably lower TTFT.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.deploy import Deployment, ServeEngine


def main():
    cfg = get_arch("qwen3-1.7b").smoke
    # program + quick calibration so the served model is the paper's artifact
    dep = Deployment.program(cfg, key=0)
    dep.advance(hours=24)
    report = dep.calibrate(4, steps=15, lr=3e-3, seq_len=32)
    print(report.summary())

    session = dep.serve()
    print(session.describe())

    # 8 requests with ragged prompt lengths, admitted while earlier ones
    # are mid-decode — 4 slots, recycled as requests finish. Temperature
    # sampling applies from the FIRST generated token, per-request keys.
    engine = ServeEngine(session, max_slots=4, max_len=48, prefill_chunk=8)
    key = jax.random.PRNGKey(0)
    reqs = []
    for i in range(8):
        lk, pk, sk, key = jax.random.split(key, 4)
        plen = int(jax.random.randint(lk, (), 4, 16))
        prompt = jax.random.randint(pk, (plen,), 0, cfg.vocab)
        reqs.append(
            engine.submit(prompt, max_new=16, temperature=0.8, key=sk)
        )
        engine.step()  # requests stream in while the batch decodes
    engine.run()

    stats = engine.stats()
    print(
        f"served {len(reqs)} ragged requests in {stats['ticks']} ticks: "
        f"{stats['decode_tokens']} decode tok in "
        f"{stats['decode_seconds']:.2f}s = {stats['decode_tok_per_s']:.1f} "
        f"tok/s on 1 CPU core; compiled computations: "
        f"{stats['compile_count']} (flat across requests)"
    )
    print("first two continuations:", reqs[0].tokens, reqs[1].tokens)

    # -- shared system prompt -> prefix-cache hits --------------------------
    # One 16-token "system prompt" opens every request; user turns differ.
    # Request 0 admits cold and leaves chunk-boundary snapshots behind;
    # requests 1..5 resume from the shared prefix (partial hits).
    sys_key, key = jax.random.split(key)
    system = np.asarray(jax.random.randint(sys_key, (16,), 0, cfg.vocab))
    chat = ServeEngine(session, max_slots=4, max_len=64, prefill_chunk=8)
    ttfts = []
    for i in range(6):
        uk, key = jax.random.split(key)
        user = np.asarray(jax.random.randint(uk, (6,), 0, cfg.vocab))
        req = chat.submit(np.concatenate([system, user]), max_new=8)
        chat.run()  # drain per request so TTFTs are comparable
        ttfts.append(req.ttft_seconds)
    st = chat.stats()
    print(
        f"shared system prompt: {st['prefix_partial_hits']} of "
        f"{st['prefix_lookups']} admissions resumed from the prefix cache; "
        f"cold TTFT {ttfts[0] * 1e3:.1f} ms -> warm median "
        f"{sorted(ttfts[1:])[len(ttfts[1:]) // 2] * 1e3:.1f} ms"
    )


if __name__ == "__main__":
    main()
