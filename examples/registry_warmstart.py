"""Calibration registry: recalibrate a fleet warm instead of cold.

``examples/fleet_lifecycle.py`` recalibrates drifted chips from
zero-initialized (output-preserving) adapters every time — correct, but
every maintenance pass pays the full Algorithm 1 step budget again. The
``repro.registry`` subsystem turns those one-off calibrations into a
fleet-wide asset:

1. Every ``calibrate(..., registry=...)`` run is persisted as a
   versioned artifact keyed by ``(model config, backend, drift/fault
   signature)``, with stability metrics against the key's promoted
   reference in a JSON sidecar.
2. The first run for a key promotes itself as the reference; later runs
   promote only when the reference has gone unstable (percentile drift,
   scale-range drift, Jensen-Shannon divergence past thresholds).
3. ``calibrate(..., registry=..., warm_start=True)`` seeds adapters AND
   optimizer moments from the nearest stable reference — a chip's own
   history when it has one, the nearest sibling's otherwise — so the
   loop starts near the optimum and an attached ``loss_threshold``
   stops it early.

This example ages a small fleet through two drift epochs and
recalibrates after each, comparing the cold path (reset adapters, full
budget) against the registry path (reset, then warm-start), both run to
the same per-cycle loss target.

Run:  PYTHONPATH=src python examples/registry_warmstart.py
"""
import tempfile

import numpy as np

from repro.configs import get_arch
from repro.fleet import Fleet
from repro.registry import CalibrationRegistry


def lifecycle(registry=None, targets=None):
    """Two drift epochs + recalibrations; returns per-cycle final
    losses and total chip-epochs spent."""
    cfg = get_arch("qwen3-1.7b").smoke
    fleet = Fleet.program(cfg, key=0, n_chips=4)
    reg_args = (
        {"registry": registry, "warm_start": True}
        if registry is not None else {}
    )
    finals, epochs = [], 0
    for cycle in range(2):
        fleet.advance(24.0)
        # each cycle models a fresh maintenance process: adapters start
        # over from zeros unless the registry re-seeds them
        fleet.reset_adapters()
        rep = fleet.calibrate(
            4, steps=8, seq_len=16,
            loss_threshold=targets[cycle] if targets else 0.0,
            **reg_args,
        )
        finals.append(np.asarray(rep.losses)[-1])
        epochs += rep.epochs_run * fleet.n_chips
        tag = (
            f"warm-started {len(rep.warm_started_chips)}/{fleet.n_chips}"
            if reg_args else "cold"
        )
        print(f"  cycle {cycle + 1}: {rep.epochs_run} epochs ({tag}), "
              f"max final loss {float(np.max(finals[-1])):.5f}")
    return finals, epochs


def main():
    print("cold arm (every recalibration from zeros, full budget):")
    cold_finals, _ = lifecycle()
    # the cold arm's achieved losses become the shared convergence
    # targets: both arms must reach them, the registry arm just gets
    # there in fewer epochs
    targets = [float(np.max(f)) * (1 + 1e-6) for f in cold_finals]

    print("cold arm, early-stopped at its own targets:")
    _, cold_epochs = lifecycle(targets=targets)

    print("registry arm (record + warm-start from nearest reference):")
    with tempfile.TemporaryDirectory() as root:
        _, warm_epochs = lifecycle(
            registry=CalibrationRegistry(root), targets=targets
        )

    saved = cold_epochs - warm_epochs
    print(f"\nchip-epochs to reach the same loss targets: "
          f"cold {cold_epochs}, registry {warm_epochs} "
          f"-> {saved} saved ({100.0 * saved / cold_epochs:.0f}%)")


if __name__ == "__main__":
    main()
