"""Paper-faithful experiment at example scale: ResNet + drift + DoRA
feature calibration vs LoRA vs backprop (Fig. 4/6 protocol), through the
deployment API's CNN-lifecycle entry (``repro.deploy.resnet_cell``).

Run:  PYTHONPATH=src python examples/calibrate_resnet.py
"""
from repro.deploy import resnet_cell


def main():
    print("running 3 calibration methods at drift=0.20, 10 samples "
          "(ResNet-8 proxy, procedural data)...")
    for method in ("dora", "lora", "backprop"):
        r = resnet_cell(method=method, rank=2, drift=0.20, samples=10,
                        calib_epochs=10)
        print(
            f"{method:9s} teacher={r.teacher_acc:.3f} "
            f"drifted={r.drifted_acc:.3f} calibrated={r.calibrated_acc:.3f} "
            f"trainable={r.trainable_fraction:.2%}"
        )


if __name__ == "__main__":
    main()
