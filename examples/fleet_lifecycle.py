"""Fleet lifecycle: one model, N chips, batched — and recalibrated only
when drift says so.

``examples/quickstart.py`` walks ONE chip through program -> drift ->
calibrate -> serve. Real deployments are fleets: every edge device gets
its own programming noise and its own drift trajectory, and each must be
restored with its own tiny SRAM adapter — never an RRAM rewrite.
``repro.fleet.Fleet`` models that as batched pytrees (a leading chip
axis on every RRAM leaf; digital peripherals shared):

1. ``Fleet.program(cfg, key, n_chips)`` — ONE stacked programming event;
   chip i is bitwise an independent ``Deployment``.
2. ``fleet.advance([...])``           — heterogeneous drift clocks: each
   chip ages at its own rate, one vmapped dispatch.
3. ``RecalibrationScheduler.tick``    — a cheap forward-free drift proxy
   (movement of the code column norms the DoRA γ divides by) decides
   WHICH chips recalibrate; the triggered subset trains in one vmapped
   DoRA loop sharing a single teacher-feature cache.
4. ``fleet.serve(i)``                 — slice any chip out and serve it;
   compiled decode steps are shared fleet-wide.

Run:  PYTHONPATH=src python examples/fleet_lifecycle.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.fleet import Fleet, RecalibrationScheduler


def main():
    cfg = get_arch("qwen3-1.7b").smoke
    n_chips = 8

    # 1. one stacked programming event for the whole fleet
    fleet = Fleet.program(cfg, key=0, n_chips=n_chips)
    print(f"programmed {n_chips} chips: "
          f"rram_bytes={fleet.rram_bytes()} (fleet total), "
          f"sram_bytes={fleet.sram_bytes()} (all side-cars)")

    # 2. heterogeneous field time: chip i ages i days per maintenance tick
    #    (chip 0 sits in a drawer; chip 7 runs hot on a dashboard)
    tick_hours = [24.0 * i for i in range(n_chips)]

    # 3. drift-driven maintenance: recalibrate a chip ONLY when its drift
    #    proxy crosses the threshold
    sched = RecalibrationScheduler(
        fleet, threshold=0.015,
        calib_args={"batch_or_samples": 8, "steps": 10, "lr": 3e-3,
                    "seq_len": 32},
    )
    for t in range(3):
        rec = sched.tick(tick_hours)
        fired = rec.recalibrated or "none"
        print(f"tick {t}: proxy={np.round(rec.proxy, 4).tolist()} "
              f"-> recalibrated: {fired}")

    report = sched.report()
    print(report.summary())
    print(f"per-chip recalibrations: {report.per_chip_recalibrations} "
          f"(naive policy: {[report.ticks] * n_chips})")

    # 4. serve any chip — the fleet shares one compiled decode stack
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab
    )}
    mses = fleet.logit_mse(batch)
    print(f"per-chip teacher/student logit MSE: {np.round(mses, 5).tolist()}")
    session = fleet.serve(int(np.argmax(report.per_chip_field_hours)))
    toks, dt = session.generate(batch["tokens"][:1, :6], gen_len=6)
    print(f"served the oldest chip: {toks.shape} in {dt:.2f}s decode; "
          f"tokens {toks[0].tolist()}")


if __name__ == "__main__":
    main()
