"""Fault recovery: inject -> detect -> recalibrate -> verify, without a
single RRAM rewrite.

``examples/fleet_lifecycle.py`` handles the SOFT failure mode: drift,
a diffusion every chip suffers gradually. This example walks the HARD
one — a chip in the fleet develops stuck cells (forming/endurance
failure, pinned to a conductance rail; drift can't move them and a
rewrite can't fix them) — and shows the non-ideality suite closing the
loop digitally:

1. ``fleet.inject(stuck_at(...), chips=[...])`` — faults apply at code
   READ-BACK: the pristine codes stay resident, every backend and the
   prepared serve path read the same faulty view.
2. ``Fleet.hard_fault_proxy`` — the MAX single-column norm jump, a
   signature drift's distributed diffusion cannot produce — separates
   the broken chip from a merely-drifted one, forward-free.
3. ``RecalibrationScheduler(hard_threshold=...)`` routes the broken
   chip down the hard path (double calibration effort, permanent flag
   in the ``FleetReport``) and the drifted chip down the normal path.
4. Verify: per-chip teacher/student logit MSE before and after — DoRA's
   SRAM side-cars absorb the fault; the array is never reprogrammed.

Run:  PYTHONPATH=src python examples/fault_recovery.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.faults import stuck_at
from repro.fleet import Fleet, RecalibrationScheduler


def main():
    cfg = get_arch("qwen3-1.7b").smoke
    fleet = Fleet.program(cfg, key=0, n_chips=3)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab
    )}

    # chip 0 develops stuck cells in the field; chips 1/2 only drift
    fleet.advance([50.0, 300.0, 0.5])
    mse_before = fleet.logit_mse(batch)
    fleet.inject(stuck_at(key=7, rate=0.05), chips=[0])
    mse_faulted = fleet.logit_mse(batch)
    print(f"teacher/student logit MSE per chip:")
    print(f"  drifted           : {np.round(mse_before, 3).tolist()}")
    print(f"  chip 0 stuck cells: {np.round(mse_faulted, 3).tolist()}")

    # detection is forward-free: the drift proxy reads diffuse movement,
    # the hard proxy reads single-column jumps only real damage makes
    print(f"drift proxy: {np.round(fleet.drift_proxy(), 3).tolist()}")
    print(f"hard  proxy: {np.round(fleet.hard_fault_proxy(), 3).tolist()}")

    sched = RecalibrationScheduler(
        fleet, threshold=0.02, hard_threshold=0.3,
        calib_args={"batch_or_samples": 8, "steps": 10, "lr": 3e-3,
                    "seq_len": 32},
    )
    rec = sched.tick(0.0)  # maintenance visit: no extra aging
    print(f"hard-fault path: chips {rec.hard_faulted} "
          f"({rec.hard_report.epochs_run} epochs); "
          f"drift path: chips {rec.recalibrated}")

    mse_after = fleet.logit_mse(batch)
    print(f"  recalibrated      : {np.round(mse_after, 3).tolist()}")
    recovered = (mse_faulted[0] - mse_after[0]) / mse_faulted[0]
    print(f"chip 0 recovered {100 * recovered:.0f}% of its error — "
          f"SRAM side-cars only, zero RRAM writes")

    report = sched.report()
    print(report.summary())
    print(f"flagged for replacement: chips {report.hard_faulted_chips} "
          f"(the damage is physical; DoRA buys serviceable accuracy "
          f"until the swap)")


if __name__ == "__main__":
    main()
