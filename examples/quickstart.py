"""Quickstart: the paper's mechanism as a device lifetime, in one object.

The whole story is a timeline — program RRAM once, let conductance drift
in the field, restore accuracy with SRAM-resident DoRA side-cars, never
rewrite the array. ``repro.deploy.Deployment`` expresses it directly:

1. ``Deployment.program``  — deploy a small LM onto the simulated
   crossbar (programming event; the array is now FIXED).
2. ``dep.advance(hours)``  — the drift clock: field time passes,
   conductances relax, accuracy degrades.
3. ``dep.calibrate``       — feature-based DoRA (Algorithm 1+2): only
   the SRAM side-cars train; zero RRAM writes.
4. ``dep.serve``           — serve the calibrated student (DoRA
   magnitudes merged, Algorithm 2 line 12).

...and because drift keeps happening, steps 2-3 repeat forever on the
same deployment — that loop is the paper's lifetime claim.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_arch
from repro.deploy import Deployment


def main():
    cfg = get_arch("qwen3-1.7b").smoke  # reduced same-family config (CPU)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (4, 32), 0, cfg.vocab
    )}

    # 1. programming event: teacher trained elsewhere, deployed onto RRAM
    dep = Deployment.program(cfg, key=0)
    gap0 = dep.logit_mse(batch, use_adapters=False)
    print(f"teacher/student logit MSE after programming: {gap0:.5f}")

    # 2. a day in the field: conductance relaxation, no reprogramming
    dep.advance(hours=24)
    gap1 = dep.logit_mse(batch, use_adapters=False)
    print(f"after 24h of drift:                          {gap1:.5f}")

    # 3. calibration: ONLY the SRAM side-cars train (~2-3% of params)
    report = dep.calibrate(batch, steps=20, lr=3e-3)
    print(report.summary())
    gap2 = dep.logit_mse(batch)
    print(f"after calibration:                           {gap2:.5f} "
          f"({100 * (1 - gap2 / gap1):.1f}% of the drift gap recovered, "
          "zero RRAM writes)")

    # 4. serve the calibrated deployment
    session = dep.serve()
    print(session.describe())
    toks, dt = session.generate(batch["tokens"][:, :8], gen_len=8)
    print(f"served {toks.shape} (decode steps: {dt:.2f}s); "
          f"first row: {toks[0].tolist()}")

    # ...time keeps passing: drift again, recalibrate again — same array
    dep.advance(hours=168)
    report2 = dep.calibrate(batch, steps=20, lr=3e-3)
    print(f"one week later, recalibrated: feature MSE "
          f"{report2.initial_loss:.6f} -> {report2.final_loss:.6f}")


if __name__ == "__main__":
    main()
