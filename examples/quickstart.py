"""Quickstart: the paper's mechanism end-to-end in ~60 lines.

1. Build a small LM ("teacher", trained weights stand-in).
2. Deploy it onto the simulated RRAM crossbar -> conductance drift
   degrades it (teacher/student disagreement).
3. Calibrate with feature-based DoRA (Algorithm 1+2): only the SRAM
   side-cars train; the RRAM array is never written.
4. Serve with the calibrated student.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.calibrate import CalibState, make_calib_step, program_model
from repro.models import transformer as T
from repro.optim.adam import AdamW, adamw_init


def main():
    arch = get_arch("qwen3-1.7b")
    cfg = arch.smoke  # reduced same-family config (CPU-friendly)
    key = jax.random.PRNGKey(0)

    # 1. teacher ("DNN trained on GPU")
    params = T.init_params(key, cfg)

    # 2. deployment: program + drift (the RRAM array is now FIXED)
    student_base = program_model(params["base"], cfg.rram, jax.random.PRNGKey(1))

    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
    t_logits = T.forward(params, batch, cfg, use_adapters=False)
    s_logits = T.forward(
        {"base": student_base, "adapters": {}}, batch, cfg, use_adapters=False
    )
    gap = float(jnp.mean((t_logits - s_logits).astype(jnp.float32) ** 2))
    print(f"teacher/student logit MSE after drift: {gap:.5f}")

    # 3. calibration: ONLY adapters train (2-3% of params, zero RRAM writes)
    state = CalibState(
        params["base"], student_base, params["adapters"],
        adamw_init(params["adapters"]), jnp.zeros((), jnp.int32),
    )
    step = jax.jit(make_calib_step(cfg, AdamW(lr=3e-3)))
    for i in range(20):
        state, metrics = step(state, batch)
        if i % 5 == 0:
            print(f"  calib step {i:3d}  feature MSE {float(metrics['loss']):.6f}")

    # 4. calibrated student
    c_logits = T.forward(
        {"base": state.student_base, "adapters": state.adapters}, batch, cfg
    )
    gap2 = float(jnp.mean((t_logits - c_logits).astype(jnp.float32) ** 2))
    print(f"teacher/student logit MSE after calibration: {gap2:.5f}")
    print(f"recovered {100 * (1 - gap2 / gap):.1f}% of the drift gap, "
          "with zero RRAM writes")


if __name__ == "__main__":
    main()
